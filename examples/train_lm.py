"""End-to-end LM training example (train -> crash -> resume -> QAT ->
compile for serving).

Trains a reduced model on the synthetic Markov stream for a few hundred
steps, demonstrates checkpoint/restart, then QAT-finetunes and compiles
the result into its constant-parameter serving form.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch ...]
(Use --preset 100m --steps 300 on real hardware for the ~100M config.)
"""
import argparse
import pathlib
import shutil
import tempfile

from repro.launch import train as trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    ckpt = pathlib.Path(tempfile.mkdtemp(prefix="repro_ckpt_"))
    common = ["--arch", args.arch, "--preset", args.preset,
              "--seq", str(args.seq), "--batch", str(args.batch),
              "--ckpt-dir", str(ckpt), "--ckpt-every", "50"]

    print("=== phase 1: train (will crash at 60%) ===")
    try:
        trainer.main(common + ["--steps", str(args.steps),
                               "--fail-at-step", str(int(args.steps * 0.6))])
    except SystemExit as e:
        print(f"(crashed as planned: exit {e.code})")

    print("=== phase 2: resume from latest checkpoint ===")
    metrics = trainer.main(common + ["--steps", str(args.steps), "--resume"])

    print("=== phase 3: short QAT finetune (INT7 fake-quant forward) ===")
    metrics = trainer.main(common + ["--steps", str(args.steps + 40),
                                     "--resume", "--qat"])
    print(f"final ce={metrics['ce']:.4f}")
    shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()

"""The paper, end to end: compile a sparse INT7 ResNet50 and reproduce
its tables.

1. Build ResNet50 (the paper's network), quantize + prune per SS II-A.
2. Reproduce Table I (design parameters) exactly from the architecture.
3. Reproduce Table II structure from the calibrated FPGA cost model
   (fold=4 for conv5, 4-instance 127k-ALM conv2 kernels...).
4. Reproduce the Fig 7 multi-chip partitioning and compare with the
   paper's projection and the V100 bound.
5. Run the compiled (sparse INT7) model vs the fp32 baseline on a batch
   and report logit agreement — the "0.22% accuracy delta" proxy that is
   checkable without ImageNet.

Run:  PYTHONPATH=src python examples/compile_resnet50.py [--width 0.25]
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core import partition
from repro.core.compiled_linear import compile_params
from repro.core.fpga_model import table2_model
from repro.models import resnet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=float, default=0.25,
                    help="width multiplier for the runnable demo model")
    ap.add_argument("--hw", type=int, default=64)
    args = ap.parse_args()

    print("=== Table I: key design parameters (exact reproduction) ===")
    t1 = resnet.table1()
    print(json.dumps(t1, indent=1))
    assert t1["conv2_x"]["mac_per_param"] == 3136
    assert t1["conv5_x"]["mac_per_param"] == 49
    assert all(row["total_macs_m"] == 218 for row in t1.values())

    print("=== Table II: calibrated cost model vs actuals ===")
    t2 = table2_model()
    for corner in ("conv2", "conv5"):
        m, a = t2[corner]["model"], t2[corner]["actual"]
        print(f" {corner}: fold model={m['fold']} actual={a['folding']} | "
              f"ALM/kernel model={m['alm_per_kernel']/1e3:.0f}k "
              f"actual={a['alm_per_kernel']/1e3:.0f}k | "
              f"MOPs/ALM model={m['mops_per_alm']:.0f} actual={a['mops_per_alm']}")

    print("=== Fig 7: multi-chip partitioning ===")
    f7 = partition.fig7_projection()
    print(json.dumps({k: f7[k] for k in ("at_paper_target", "model_best",
                                         "gx550_scaling")},
                     indent=1, default=lambda o: round(o, 2)))

    print("=== Compiled sparse-INT7 ResNet50 vs fp32 sparse baseline ===")
    # The paper starts from an ALREADY 80%-sparse model (Movidius/AMC);
    # we emulate that by pre-pruning, then measure what compilation adds
    # (INT7 quantization) — the analogue of the paper's 0.22% delta.
    cfg = resnet.ResNetConfig(width_mult=args.width, num_classes=100,
                              in_hw=args.hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)

    def presparsify(p):
        if isinstance(p, nn.Param) and nn.compilable(p.kind) and p.value.ndim == 2:
            from repro.core.compiled_linear import balanced_prune_codes
            keep = max(8, int(p.value.shape[0] * 0.2) // 8 * 8)
            qt = balanced_prune_codes(p.value.astype(jnp.float32), keep)
            return nn.Param(qt.dequantize().astype(p.value.dtype) * 0 +
                            jnp.where(qt.values != 0, p.value, 0.0),
                            p.axes, p.kind)
        return p

    sparse_params = jax.tree.map(presparsify, params,
                                 is_leaf=lambda x: isinstance(x, nn.Param))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, args.hw, args.hw, 3))
    ref = resnet.apply(nn.unbox(sparse_params), x, cfg)
    # every serving mode runs the fused implicit-GEMM conv pipeline; all
    # must land within quantization tolerance of the dense (pre-refactor
    # baseline) path on the same sparse weights
    from repro.core.compiled_linear import SERVE_MODES
    for mode in SERVE_MODES:
        if mode == "dense":
            continue
        compiled = nn.unbox(compile_params(sparse_params, mode=mode,
                                           sparsity=0.8))
        out = resnet.apply(compiled, x, cfg)
        top1_match = float(jnp.mean((jnp.argmax(out, -1) ==
                                     jnp.argmax(ref, -1)).astype(jnp.float32)))
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        print(f" {mode:12s} compilation (INT7) error on the sparse model: "
              f"logits rel err {rel:.4f}; top-1 agreement {top1_match:.0%} "
              f"(paper: 0.22% top-1 delta)")
        assert rel < 0.15, (mode, rel)
    print("compile_resnet50 OK")


if __name__ == "__main__":
    main()

"""The paper's deployment, scaled out: a FLEET of Fig 7 pipelines behind
one admission queue.

The paper's multi-chip story stops at one pipeline (53k im/s across 9
GX280s).  Serving "heavy traffic from millions of users" needs the layer
above it — N data-parallel replicas of the layer-pipelined network over
disjoint device groups, with the front door doing admission + least-
loaded routing (the HPIPE scale-out move).  This script runs that layer
end to end on local devices:

1. Project the single-pipeline Fig 7 numbers with the analytic FPGA
   model, then scale by the replica count — the fleet-law aggregate.
2. Build a ``ResNetFrontend``: ONE compiled param tree, N replicas x S
   stages on device groups carved from the local device list (fan a CPU
   host out with XLA_FLAGS=--xla_force_host_platform_device_count=N).
3. Stream a wave of differently-sized requests through the shared queue
   and verify every request's logits are bit-identical to the
   single-device compiled path at the same microbatch granularity.
4. Report aggregate im/s, per-replica routing, queue depth, and request
   latency p50/p95.

Run:  PYTHONPATH=src python examples/serve_resnet50_fleet.py \
          [--replicas 2 --stages 2 --width 0.25 --hw 32 --mode int8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core import partition
from repro.core.compiled_linear import compile_params
from repro.core.fpga_model import FIG7
from repro.models import resnet
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.pipeline import reference_logits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--mode", default="int8",
                    choices=("int8", "cfmm", "sparse_cfmm", "bitserial"))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--microbatch", type=int, default=2)
    args = ap.parse_args()

    print("=== Fig 7 projection, scaled to a fleet ===")
    blocks50 = resnet.resnet50_conv_blocks()
    proj = partition.solve_max_throughput(blocks50)
    print(f" one pipeline: {proj.achieved_im_s:.0f} im/s on {proj.n_chips} "
          f"GX280s ({proj.im_s_per_chip:.0f} im/s/chip; paper claims "
          f"{FIG7['im_s_per_chip_gx280']})")
    print(f" {args.replicas} replicas: {args.replicas * proj.achieved_im_s:.0f} "
          f"im/s aggregate on {args.replicas * proj.n_chips} chips — "
          f"replicas share nothing but the front door")

    print(f"=== executed fleet (width {args.width}, {args.hw}x{args.hw}, "
          f"mode {args.mode}, {args.replicas} replicas x {args.stages} "
          f"stages) ===")
    cfg = resnet.ResNetConfig(width_mult=args.width, num_classes=100,
                              in_hw=args.hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    compiled = nn.unbox(compile_params(params, mode=args.mode, sparsity=0.8))
    fe = ResNetFrontend(cfg, compiled, mode=args.mode,
                        n_replicas=args.replicas, n_stages=args.stages,
                        microbatch=args.microbatch)
    rng = np.random.RandomState(1)
    sizes = [args.microbatch * (1 + i % 3) + i % 2        # ragged sizes
             for i in range(args.requests)]
    reqs = [FrontendRequest(rid=i, images=rng.randn(
        s, args.hw, args.hw, 3).astype(np.float32))
        for i, s in enumerate(sizes)]
    fe.run(reqs)                               # compiles every replica
    for r in reqs:
        ref = reference_logits(compiled, cfg, jnp.asarray(r.images),
                               args.microbatch)
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      np.asarray(ref))
    print(f" every request bit-identical to the single-device compiled "
          f"path ({args.requests} requests, sizes {sizes})")

    fe.reset_stats()
    wave = [FrontendRequest(rid=i, images=r.images)
            for i, r in enumerate(reqs)]
    t0 = time.time()
    fe.run(wave)
    dt = time.time() - t0
    st = fe.stats()
    n_img = sum(sizes)
    print(f" wave 2 (warm): {n_img} images in {dt * 1e3:.0f} ms "
          f"({n_img / dt:.1f} im/s wall on "
          f"{len(jax.devices())} local device(s))")
    print(f" latency p50 {st['latency_p50_s'] * 1e3:.1f} ms | p95 "
          f"{st['latency_p95_s'] * 1e3:.1f} ms | max queue depth "
          f"{st['max_queue_depth']}")
    for r in range(st["n_replicas"]):
        rs = st["replicas"][r]
        print(f" replica {r}: {st['rows_dispatched'][r]:3d} rows / "
              f"{st['requests_dispatched'][r]} requests | bubble "
              f"{rs['bubble_fraction']:.2f} | stages on "
              f"{rs['stage_devices']}")
    print(" the fleet divides weights over stages WITHIN a replica and "
          "replicates across replicas;\n quantization domains are "
          "per-row, so microbatch neighbours cannot change anyone's bits")
    print("serve_resnet50_fleet OK")


if __name__ == "__main__":
    main()

"""Fig 7, executed: plan multi-chip stages with the paper's partitioner,
then actually run the partitioned ResNet as a pipeline across local
devices with persistent per-stage weights and 8-bit links.

1. Partition full ResNet50 with the calibrated FPGA model
   (core/partition.solve_max_throughput) — the paper's Fig 7 projection.
2. Re-balance the chip packing to N executable stages (StagePlans) and
   launch a width-scaled compiled ResNet through the pipeline engine on
   the local devices (fan a CPU host out with
   XLA_FLAGS=--xla_force_host_platform_device_count=N).
3. Verify the pipelined output is bit-identical to the single-device
   compiled path, then report achieved im/s (wall + pipeline-law) next
   to the Fig 7 projection and the paper's claim.

Run:  PYTHONPATH=src python examples/serve_resnet50_pipeline.py \
          [--stages 4 --width 0.25 --hw 32 --mode sparse_cfmm]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core import partition
from repro.core.compiled_linear import compile_params
from repro.core.fpga_model import FIG7
from repro.models import resnet
from repro.serving.pipeline import PipelineEngine, reference_logits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--mode", default="int8",
                    choices=("int8", "cfmm", "sparse_cfmm", "bitserial"))
    ap.add_argument("--images", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=2)
    args = ap.parse_args()

    print("=== Fig 7 projection (full ResNet50, analytic FPGA model) ===")
    blocks50 = resnet.resnet50_conv_blocks()
    proj = partition.solve_max_throughput(blocks50)
    print(f" model: {proj.im_s_per_chip:.0f} im/s/chip on {proj.n_chips} "
          f"GX280s at {proj.achieved_im_s:.0f} im/s total "
          f"(paper claims {FIG7['im_s_per_chip_gx280']} im/s/chip); "
          f"max link {proj.max_link_gbps:.1f} Gbps")
    plans50 = proj.stage_plans(blocks50, args.stages)
    print(f" as {len(plans50)} executable stages: " + "; ".join(
        f"S{p.index}: blocks {p.block_ids[0]}..{p.block_ids[-1]} "
        f"({p.link_gbps(proj.achieved_im_s):.0f} Gbps out)"
        if p.link_bytes else
        f"S{p.index}: blocks {p.block_ids[0]}..{p.block_ids[-1]}"
        for p in plans50))

    print(f"=== executed pipeline (width {args.width}, {args.hw}x{args.hw}, "
          f"mode {args.mode}, {args.stages} stages) ===")
    cfg = resnet.ResNetConfig(width_mult=args.width, num_classes=100,
                              in_hw=args.hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    compiled = nn.unbox(compile_params(params, mode=args.mode, sparsity=0.8))
    blocks = resnet.conv_blocks_for(cfg)
    plan = partition.partition(blocks, 10_000.0).stage_plans(blocks,
                                                             args.stages)
    engine = PipelineEngine(cfg, compiled, mode=args.mode, plan=plan,
                            microbatch=args.microbatch)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (args.images, args.hw, args.hw, 3)))
    got = engine.run_batch(x)                  # compiles every stage
    ref = reference_logits(compiled, cfg, jnp.asarray(x), args.microbatch)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    print(" pipelined output bit-identical to the single-device compiled "
          "path")
    t0 = time.time()
    engine.run_batch(x)
    wall = time.time() - t0
    st = engine.stats()
    for s in range(st["n_stages"]):
        sb = st["stage_blocks"][s]
        print(f" stage {s} [{st['stage_devices'][s]}]: blocks "
              f"{sb[0]}..{sb[-1]}, {st['stage_weight_bytes'][s] / 1e3:.0f} kB "
              f"constant weights resident")
    for e, b in enumerate(st["edge_bytes"]):
        print(f" edge {e}->{e + 1}: {b['int8_bytes']} B int8/microbatch "
              f"(planned {st['planned_link_bytes'][e] * args.microbatch} B) "
              f"+ {b['meta_bytes']} B scale")
    print(f" achieved: {args.images / wall:.1f} im/s wall on "
          f"{len(set(st['stage_devices']))} device(s), bubble "
          f"{st['bubble_fraction']:.2f} (analytic "
          f"{st['bubble_fraction_analytic']:.2f})")
    print(f" Fig 7 context: the projection above sustains "
          f"{proj.achieved_im_s:.0f} im/s on {proj.n_chips} chips; this "
          f"demo runs the same partitioning discipline end to end on "
          f"local devices.")
    print("serve_resnet50_pipeline OK")


if __name__ == "__main__":
    main()

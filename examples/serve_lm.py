"""Batched serving example: all four compiled-weight modes side by side.

Serves the same request batch with dense bf16, INT7 (int8 storage), CFMM
and 80%-sparse bitmap-packed weights, and reports agreement + packed
sizes.  On TPU the cfmm/sparse modes dispatch to the Pallas kernels; here
the jnp lowerings run (same numerics).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro import nn
from repro.launch.train import build_cfg
from repro.models import lm
from repro.serving.engine import Request, ServingEngine

cfg = build_cfg("smollm_360m", "tiny")
params = lm.init(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(0)
prompts = [list(rng.randint(1, cfg.vocab, size=12)) for _ in range(4)]

results = {}
for mode in ("dense", "int8", "cfmm", "sparse_cfmm"):
    engine = ServingEngine(cfg, params, mode=mode, batch_slots=2,
                           max_seq=40)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    results[mode] = [r.tokens_out for r in reqs]
    nbytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(engine.params))
    print(f"mode={mode:12s} params={nbytes/1e6:6.2f} MB  "
          f"{sum(len(t) for t in results[mode])} tokens in {dt:.1f}s")

agree = np.mean([results["dense"][i] == results["int8"][i]
                 for i in range(len(prompts))])
print(f"dense vs int8 greedy-token agreement: {agree:.0%} "
      f"(INT7 ~ FP32, paper: 0.22% accuracy delta)")
print("serve_lm OK")

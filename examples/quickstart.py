"""Quickstart: the paper's technique in five minutes.

1. Quantize a weight matrix to INT7 (per-output-channel, paper SS II-A).
2. Decompose into CFMM form — sign / 32 odd magnitudes / free shifts
   (paper SS II-E.1) and verify the counting argument.
3. Run the three equivalent compiled matmul dataflows and check they are
   bit-exact against each other.
4. Prune to 80% sparsity, bitmap-pack, and show the storage win that
   becomes decode bandwidth on TPU.
5. Compile a whole model's parameters and serve one batch.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cfmm
from repro.core.compiled_linear import (balanced_prune_codes, bitmap_pack,
                                        bitmap_unpack, compile_params)
from repro.core.quantize import quantize_int7, quantization_error

key = jax.random.PRNGKey(0)

# -- 1. INT7 quantization ---------------------------------------------------
w = jax.random.normal(key, (512, 256)) * 0.05
qt = quantize_int7(w, axis=-1)
print(f"1. INT7 quantization: relative L2 error "
      f"{float(quantization_error(w)):.4%} (paper: 0.22% top-1 loss)")

# -- 2. CFMM decomposition --------------------------------------------------
sign, mag_idx, shift = cfmm.decompose(qt.values)
assert (np.asarray(cfmm.reconstruct(sign, mag_idx, shift))
        == np.asarray(qt.values, np.int32)).all()
print(f"2. CFMM: {cfmm.unique_product_count(qt.values)} unique odd product "
      f"magnitudes (paper: <= {cfmm.N_UNIQUE_PRODUCTS}); "
      f"decompose/reconstruct exact")

# -- 3. Three equivalent compiled dataflows ---------------------------------
x_q = jax.random.randint(jax.random.PRNGKey(1), (8, 512), -127, 127, jnp.int8)
y_table = cfmm.cfmm_matmul_exact(x_q, cfmm.pack(qt.values, qt.scale))
y_mxu = cfmm.cfmm_matmul_int8(x_q, qt.values)
y_bits = cfmm.bitserial_matmul(x_q, qt.values)
assert (np.asarray(y_table) == np.asarray(y_mxu)).all()
assert (np.asarray(y_mxu) == np.asarray(y_bits)).all()
print("3. product-table == decode+MXU == bit-serial dataflows: bit-exact")

# -- 4. 80% sparsity, bitmap packing ----------------------------------------
keep = int(512 * 0.2)
codes = balanced_prune_codes(w, keep).values
bitmap, values = bitmap_pack(codes, keep)
assert (np.asarray(bitmap_unpack(bitmap, values)) == np.asarray(codes)).all()
dense_bf16 = 512 * 256 * 2
packed = bitmap.size + values.size
print(f"4. 80% sparse bitmap pack: {packed} B vs {dense_bf16} B bf16 "
      f"({dense_bf16 / packed:.1f}x less weight traffic at decode)")

# -- 5. Compile + serve a tiny model -----------------------------------------
from repro.launch.train import build_cfg
from repro.models import lm
from repro import nn

cfg = build_cfg("smollm_360m", "tiny")
params = lm.init(key, cfg)
served = compile_params(params, mode="sparse_cfmm", sparsity=0.8)
toks = jax.random.randint(key, (2, 16), 1, cfg.vocab)
cache = nn.unbox(lm.cache_init(cfg, 2, 32))
logits, cache = lm.forward_prefill(nn.unbox(served), {"tokens": toks},
                                   cfg, cache)
print(f"5. compiled sparse-INT7 model served a prompt: logits "
      f"{logits.shape}, finite={bool(jnp.isfinite(logits.astype(jnp.float32)).all())}")
print("quickstart OK")

"""Model zoo through one serving stack: claim → projection → executed.

The paper's compiled-CNN recipe (constant int8 parameters burned into
the kernels, per-row quantized activation edges, pipeline partitioning
at those edges) is model-agnostic: anything expressible as the conv DAG
IR (models/graph.py) serves through the same PipelineEngine +
ResNetFrontend unchanged.  This driver proves it on the whole zoo:

  resnet50      — the paper's network (bottleneck residuals)
  mobilenet_v2  — inverted residuals on the depthwise Pallas kernel,
                  no-ReLU linear bottlenecks quantized via max|y|
  repvgg_a0     — 3x3 + 1x1 + identity branches folded into ONE 3x3
                  conv per block at compile time (train-time DAG,
                  deploy-time chain)

Per model: the analytic FPGA projection for the full-scale network
(partition.solve_max_throughput — the Fig 7 discipline applied beyond
ResNet), then a width-scaled instance executed through the replicated
fleet frontend with the output gated bit-identical to the single-device
compiled reference.

Run:  PYTHONPATH=src python examples/serve_model_zoo.py \
          [--width 0.25 --hw 32 --stages 2 --replicas 1 --mode int8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core import partition
from repro.core.compiled_linear import compile_params
from repro.models import mobilenet_v2 as mb
from repro.models import repvgg, resnet
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.pipeline import reference_logits


def _zoo(args):
    """name -> (claim line, full-scale cfg, executable cfg + params)."""
    w, hw = args.width, args.hw
    r = resnet.ResNetConfig(width_mult=w, num_classes=100, in_hw=hw)
    m = mb.MobileNetV2Config(width_mult=w, num_classes=100, in_hw=hw)
    v = repvgg.RepVGGConfig(width_mult=w, num_classes=100, in_hw=hw)
    vu = v.init(jax.random.PRNGKey(0))
    return {
        "resnet50": (
            "the paper's network: bottleneck residuals, shortcut adds in "
            "the Collector epilogue",
            resnet.ResNetConfig(), r, r.init(jax.random.PRNGKey(0))),
        "mobilenet_v2": (
            "depthwise separable blocks on the tap-MAC Pallas kernel; "
            "linear bottlenecks quantize via max|y| (no ReLU needed)",
            mb.MobileNetV2Config(), m, m.init(jax.random.PRNGKey(0))),
        "repvgg_a0": (
            f"{sum(1 for _ in repvgg.block_specs(v))} three-branch train "
            "blocks re-parameterized into single 3x3 convs at compile "
            "time — the served chain never sees the 1x1/identity branches",
            repvgg.RepVGGConfig(), v, v.fuse(vu)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--mode", default="int8",
                    choices=("int8", "cfmm", "sparse_cfmm"))
    ap.add_argument("--images", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=2)
    args = ap.parse_args()

    for name, (claim, full_cfg, cfg, params) in _zoo(args).items():
        print(f"\n=== {name} ===")
        print(f" claim: {claim}")

        blocks = full_cfg.graph().blocks()
        proj = partition.solve_max_throughput(blocks)
        print(f" projection (full scale, {len(blocks)} conv blocks, "
              f"analytic FPGA model): {proj.im_s_per_chip:.0f} im/s/chip "
              f"on {proj.n_chips} chip(s), max link "
              f"{proj.max_link_gbps:.1f} Gbps")

        compiled = nn.unbox(compile_params(params, mode=args.mode,
                                           sparsity=0.8))
        x = np.asarray(jax.random.normal(
            jax.random.PRNGKey(1),
            (args.images, cfg.in_hw, cfg.in_hw, 3)))
        ref = np.asarray(reference_logits(compiled, cfg, jnp.asarray(x),
                                          args.microbatch))
        fe = ResNetFrontend(cfg, compiled, mode=args.mode,
                            n_replicas=args.replicas,
                            n_stages=args.stages,
                            microbatch=args.microbatch)
        warm = FrontendRequest(rid=0, images=x)
        fe.run([warm])                         # compiles every stage
        np.testing.assert_array_equal(np.asarray(warm.logits), ref)
        t0 = time.time()
        req = FrontendRequest(rid=1, images=x)
        fe.run([req])
        wall = time.time() - t0
        np.testing.assert_array_equal(np.asarray(req.logits), ref)
        st = fe.replicas[0].stats()
        n_blocks = sum(len(b) for b in st["stage_blocks"])
        print(f" executed (width {args.width}, {cfg.in_hw}x{cfg.in_hw}, "
              f"mode {args.mode}, {args.replicas} replica(s) x "
              f"{args.stages} stage(s), {n_blocks} conv blocks): "
              f"{args.images / wall:.1f} im/s, output bit-identical to "
              f"the single-device compiled path; inter-stage links "
              f"{st['planned_link_bytes']} B/img")

    print("\nserve_model_zoo OK")


if __name__ == "__main__":
    main()

"""shard_map all-to-all MoE dispatch vs the scatter reference, on a real
2x2 host-device mesh (subprocess: needs its own device-count override)."""
import subprocess
import sys

import pytest

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.distributed.moe_a2a import a2a_expert_exchange

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
E, d, T, K = 8, 16, 32, 2
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (T, d), jnp.float32)
logits = jax.random.normal(jax.random.fold_in(key, 1), (T, E))
probs = jax.nn.softmax(logits, -1)
gates, idx = jax.lax.top_k(probs, K)
gates = gates / gates.sum(-1, keepdims=True)
# simple per-expert linear "FFN": y = x * (expert_id + 1)
W = (jnp.arange(E, dtype=jnp.float32) + 1.0)

def experts_apply_local(shard_w):
    def f(x_e):  # (E_loc, S, d)
        return x_e * shard_w[:, None, None]
    return f

E_loc = E // mesh.shape["model"]
# the local expert weights per model shard (here derived inside shard_map
# via a constant — each shard scales by its own expert ids)
def experts_apply(x_e):
    # shard-local expert ids: axis index over 'model'
    i = jax.lax.axis_index("model")
    ids = i * E_loc + jnp.arange(E_loc, dtype=jnp.float32)
    return x_e * (ids + 1.0)[:, None, None]

with mesh:
    xs = jax.device_put(x, NamedSharding(mesh, P(("data", "model"))))
    out = a2a_expert_exchange(xs, idx, gates, experts_apply, E, mesh,
                              capacity_factor=8.0)
    out = np.asarray(out)

# reference: dense combine
ref = np.zeros_like(np.asarray(x))
for t in range(T):
    for j in range(K):
        e = int(idx[t, j])
        ref[t] += float(gates[t, j]) * np.asarray(x[t]) * (e + 1.0)
err = np.abs(out - ref).max() / np.abs(ref).max()
print("A2A_MOE_OK" if err < 1e-4 else f"A2A_MOE_MISMATCH {err}")
"""


@pytest.mark.slow
def test_a2a_dispatch_matches_dense_reference(tmp_path):
    script = tmp_path / "a2a.py"
    script.write_text(SCRIPT)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, timeout=420,
                       env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "A2A_MOE_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])

import os

# Kernel tests exercise Pallas in interpret mode; smoke tests must see the
# single real CPU device (the 512-device fan-out belongs to dryrun only).
os.environ.setdefault("REPRO_PALLAS", "interpret")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import cfmm
from repro.core.quantize import quantize_int7

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def test_decompose_reconstruct_all_int7_values():
    codes = jnp.arange(-63, 64, dtype=jnp.int8)
    s, m, sh = cfmm.decompose(codes)
    back = cfmm.reconstruct(s, m, sh)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.arange(-63, 64, dtype=np.int32))
    # paper counting argument: 32 odd magnitudes, shift <= 5
    assert cfmm.N_UNIQUE_PRODUCTS == 32
    assert int(jnp.max(sh)) <= cfmm.MAX_SHIFT == 5


def test_unique_products_at_most_32():
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
    qt = quantize_int7(w)
    assert cfmm.unique_product_count(qt.values) <= 32


def test_product_table_is_odd_multiples():
    x = jnp.array([3, -5, 0], jnp.int8)
    tab = cfmm.product_table(x)
    assert tab.shape == (3, 32)
    np.testing.assert_array_equal(np.asarray(tab[0]),
                                  3 * np.asarray(cfmm.ODD_VALUES))


@given(st.integers(0, 2**31 - 1), st.integers(1, 16),
       st.integers(2, 40), st.integers(1, 24))
def test_matmul_dataflows_bit_exact(seed, M, K, N):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (K, N))
    qt = quantize_int7(w)
    x = jax.random.randint(jax.random.fold_in(key, 1), (M, K),
                           -127, 128, jnp.int8)
    y_table = cfmm.cfmm_matmul_exact(x, cfmm.pack(qt.values, qt.scale))
    y_mxu = cfmm.cfmm_matmul_int8(x, qt.values)
    y_bits = cfmm.bitserial_matmul(x, qt.values)
    ref = np.asarray(x, np.int32) @ np.asarray(qt.values, np.int32)
    np.testing.assert_array_equal(np.asarray(y_table), ref)
    np.testing.assert_array_equal(np.asarray(y_mxu), ref)
    np.testing.assert_array_equal(np.asarray(y_bits), ref)


def test_flops_amortization_accounting():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    qt = quantize_int7(w)
    acc = cfmm.cfmm_flops_saved(qt.values, n_common_uses=2304)
    assert acc["amortization"] > 70  # 2304 products per ~30 adds (Fig 3)


def test_cluster_rows_raises_block_sparsity():
    """Constant-weight row clustering concentrates support into blocks the
    trace-time-specialised kernel can skip (paper's dropped MACs)."""
    import numpy as np
    from repro.core.sparsity import block_sparsity, cluster_rows
    from repro.core.quantize import quantize_int7
    rng = np.random.RandomState(0)
    # structured sparse weights: two row-populations with disjoint support
    w = np.zeros((128, 64), np.float32)
    rows_a = rng.choice(128, 64, replace=False)
    mask_a = np.zeros(128, bool); mask_a[rows_a] = True
    w[mask_a, :16] = rng.randn(64, 16)
    w[~mask_a, 48:] = rng.randn(64, 16)
    w = w[rng.permutation(128)]          # shuffle rows
    q = quantize_int7(jnp.asarray(w)).values
    before = block_sparsity(q, (32, 16))
    perm = cluster_rows(np.asarray(q), block_k=32)
    after = block_sparsity(jnp.asarray(np.asarray(q)[perm]), (32, 16))
    assert after >= before
    assert after >= 0.6                  # disjoint supports separate well

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": [jnp.ones((2,)), jnp.zeros((3,), jnp.bfloat16)]}}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 10, tree)
    restored, step = ckpt.restore(tmp_path, jax.tree.map(jnp.zeros_like, tree))
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_pointer_and_retention(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep_last=2)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1] == "step_00000005"


def test_corruption_detected(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 7, tree)
    blob = next((tmp_path / "step_00000007").glob("*.npz"))
    data = bytearray(blob.read_bytes())
    data[100] ^= 0xFF
    blob.write_bytes(bytes(data))
    with pytest.raises(IOError, match="corrupt"):
        ckpt.restore(tmp_path, tree)


def test_fallback_when_latest_is_stale(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 1, tree)
    ckpt.save(tmp_path, 2, tree)
    (tmp_path / "LATEST").write_text("step_00000099")  # bogus pointer
    assert ckpt.latest_step(tmp_path) == 2


def test_resume_determinism(tmp_path):
    """Crash/resume yields the exact same final loss as an uninterrupted run."""
    from repro.launch import train as trainer
    common = ["--arch", "smollm_360m", "--preset", "tiny", "--seq", "32",
              "--batch", "4", "--steps", "12", "--log-every", "100"]
    m_full = trainer.main(common)
    ckdir = str(tmp_path / "ck")
    with pytest.raises(SystemExit):
        trainer.main(common + ["--ckpt-dir", ckdir, "--ckpt-every", "4",
                               "--fail-at-step", "9"])
    m_resumed = trainer.main(common + ["--ckpt-dir", ckdir, "--resume"])
    assert abs(m_full["loss"] - m_resumed["loss"]) < 1e-3

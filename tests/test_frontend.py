"""Replicated-pipeline serving front-end (serving/frontend.py).

Conformance: every request's logits must be *bit-identical* to
``serving.pipeline.reference_logits`` at the engine's microbatch
granularity for every (n_replicas, n_stages, serve mode) cell, no matter
the arrival order or how requests interleave mid-flight — replicas never
share a quantization domain and neither do queue neighbours.  Plus: the
shared host-side compiled tree / per-group disjoint stage subtree spies,
least-loaded routing + admission backpressure, latency accounting, and a
forced-4-device subprocess harness (2 replicas x 2 stages on disjoint
device groups).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.launch.mesh import replica_pipeline_devices
from repro.models import resnet
from repro.obs.metrics import Reservoir
from repro.serving.frontend import (FrontendRequest, ResNetFrontend,
                                    _percentile)
from repro.serving.pipeline import reference_logits

CFG = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
MODES = ("int8", "sparse_cfmm")
MB = 2

_params_cache = {}


def _compiled(mode):
    if mode not in _params_cache:
        params = resnet.init(jax.random.PRNGKey(0), CFG)
        _params_cache[mode] = nn.unbox(
            cl.compile_params(params, mode=mode, sparsity=0.5))
    return _params_cache[mode]


def _images(n, seed=1):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                        (n, CFG.in_hw, CFG.in_hw, 3)))


_ref_cache = {}


def _reference(mode, images, microbatch):
    """Per-request reference, cached by content so the matrix doesn't
    recompile the whole-model jit for every (cell, request) pair."""
    key = (mode, microbatch, os.environ.get("REPRO_PALLAS"),
           images.tobytes())
    if key not in _ref_cache:
        _ref_cache[key] = np.asarray(reference_logits(
            _compiled(mode), CFG, jnp.asarray(images), microbatch))
    return _ref_cache[key]


def _check_vs_reference(reqs, mode, microbatch=MB):
    for r in reqs:
        assert r.done
        np.testing.assert_array_equal(
            np.asarray(r.logits), _reference(mode, r.images, microbatch))


# ---------------------------------------------------------------------------
# Conformance matrix: replicas x stages x serve mode, arrival orders
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_stages", (1, 2))
@pytest.mark.parametrize("n_replicas", (1, 2))
@pytest.mark.parametrize("mode", MODES)
def test_fleet_bit_identical_jnp(monkeypatch, mode, n_replicas, n_stages):
    """Every request equals its own per-microbatch reference — replica
    count, stage count, routing, and queue neighbours cannot change a
    single bit.  (Arrival order and mid-flight interleaving are swept in
    the dedicated tests below; microbatch-boundary odd sizes too.)"""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    x = _images(8)
    fe = ResNetFrontend(CFG, _compiled(mode), mode=mode,
                        n_replicas=n_replicas, n_stages=n_stages,
                        microbatch=MB)
    reqs = [FrontendRequest(rid=i, images=x[a:b])
            for i, (a, b) in enumerate([(0, 4), (4, 6), (6, 8)])]
    fe.run(reqs)
    _check_vs_reference(reqs, mode)


@pytest.mark.slow
@pytest.mark.parametrize("n_replicas", (1, 2))
@pytest.mark.parametrize("mode", MODES)
def test_fleet_bit_identical_interpret(monkeypatch, mode, n_replicas):
    """The fleet through the Pallas kernels in interpret mode (single
    image/microbatch, 2 stages — interpret is slow; the full lowering
    matrix for the stage programs themselves lives in test_pipeline.py,
    and routing above them is lowering-independent)."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    fe = ResNetFrontend(CFG, _compiled(mode), mode=mode,
                        n_replicas=n_replicas, n_stages=2, microbatch=1)
    reqs = [FrontendRequest(rid=i, images=_images(1, seed=i))
            for i in range(2)]
    fe.run(reqs)
    _check_vs_reference(reqs, mode, microbatch=1)


def test_arrival_order_and_interleaving_do_not_change_bits(monkeypatch):
    """The same requests through opposite arrival orders AND a wave
    submitted mid-flight (odd sizes, so partial microbatches ride along):
    every request always matches its own reference."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    x = _images(10)
    sizes = [(0, 3), (3, 4), (4, 9), (9, 10)]
    outs = {}
    for order in (1, -1):
        fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8",
                            n_replicas=2, n_stages=2, microbatch=MB)
        reqs = [FrontendRequest(rid=i, images=x[a:b])
                for i, (a, b) in enumerate(sizes)][::order]
        early, late = reqs[:2], reqs[2:]
        for r in early:
            fe.submit(r)
        for _ in range(3):                     # partially drain
            fe.step()
        for r in late:                         # interleave mid-flight
            fe.submit(r)
        while fe.step():
            pass
        _check_vs_reference(reqs, "int8")
        outs[order] = {r.rid: np.asarray(r.logits) for r in reqs}
    for rid in outs[1]:
        np.testing.assert_array_equal(outs[1][rid], outs[-1][rid])


def test_zero_row_request_completes(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=2,
                        microbatch=MB)
    req = FrontendRequest(rid=0, images=_images(4)[:0])
    fe.run([req])
    assert req.done and req.logits.shape == (0, CFG.num_classes)
    assert req.latency_s is not None


# ---------------------------------------------------------------------------
# Shared host tree + disjoint per-group stage subtrees (spies)
# ---------------------------------------------------------------------------

def _leaf_bytes(tree):
    return sum(l.nbytes for l in jax.tree.leaves(tree))


@pytest.mark.parametrize("mode", MODES)
def test_replicas_share_host_tree_and_split_stage_subtrees(monkeypatch,
                                                           mode):
    """The fleet compiles ONE host-side param tree (every replica engine
    aliases it), and each replica's device group holds exactly its own
    stages' unit subtrees — the model is divided over a replica's stages
    and replicated only across replicas."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = _compiled(mode)
    fe = ResNetFrontend(CFG, params, mode=mode, n_replicas=2, n_stages=2,
                        microbatch=MB)
    units = resnet.compiled_units(params, CFG)
    unit_bytes = {u.name: _leaf_bytes(u.params) for u in units}
    for eng in fe.replicas:
        assert eng.params is fe.params         # one compiled tree, aliased
        seen = []
        for stage in eng.pipe.stages:
            seen.extend(stage.unit_names)
            assert stage.weight_bytes() == sum(
                unit_bytes[n] for n in stage.unit_names)
        assert sorted(seen) == sorted(unit_bytes)  # disjoint + complete
    # boxed params also compile exactly once, at the front door
    boxed = resnet.init(jax.random.PRNGKey(0), CFG)
    fe2 = ResNetFrontend(CFG, boxed, mode=mode, sparsity=0.5,
                         n_replicas=2, microbatch=MB)
    assert all(eng.params is fe2.params for eng in fe2.replicas)


def test_replica_device_carving():
    """replica_pipeline_devices carves contiguous disjoint groups when
    the devices exist and wraps round-robin when they don't."""
    devs = list("abcdefgh")                    # placement is list-agnostic
    groups = replica_pipeline_devices(2, 3, devices=devs)
    assert groups == [["a", "b", "c"], ["d", "e", "f"]]
    flat = [d for g in groups for d in g]
    assert len(set(flat)) == len(flat)         # disjoint
    wrapped = replica_pipeline_devices(3, 2, devices=devs[:4])
    assert wrapped == [["a", "b"], ["c", "d"], ["a", "b"]]


# ---------------------------------------------------------------------------
# Routing, backpressure, accounting
# ---------------------------------------------------------------------------

def test_least_loaded_routing_spreads_requests(monkeypatch):
    """Two same-size requests land on different replicas (the second
    sees replica 0 loaded), and the dispatch tallies say so."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=2,
                        microbatch=MB)
    reqs = [FrontendRequest(rid=i, images=_images(4, seed=i))
            for i in range(2)]
    fe.run(reqs)
    assert sorted(r.replica for r in reqs) == [0, 1]
    st = fe.stats()
    assert st["rows_dispatched"] == [4, 4]
    assert st["requests_dispatched"] == [1, 1]


def test_admission_backpressure_holds_queue(monkeypatch):
    """With more offered rows than the fleet can absorb, the front door
    holds requests in ITS queue (bounded replica inlets) and still
    drains everything correctly."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=2,
                        n_stages=1, microbatch=MB, admit_rows=2)
    reqs = [FrontendRequest(rid=i, images=_images(2, seed=i))
            for i in range(6)]
    for r in reqs:
        fe.submit(r)
    assert len(fe.queue) == 6                  # nothing dispatched yet
    fe.step()
    assert len(fe.queue) > 0                   # held back, not dumped
    assert max(eng.pending_rows for eng in fe.replicas) <= 2 + MB
    while fe.step():
        pass
    _check_vs_reference(reqs, "int8")
    st = fe.stats()
    assert st["max_queue_depth"] == 6 and st["queue_depth"] == 0
    assert st["requests_done"] == 6


def test_admit_rows_validated_and_partial_mb_load_exact(monkeypatch):
    """admit_rows=0 would deadlock the front door (an idle replica could
    never be handed work) — rejected at construction; and pending_rows
    counts a partial microbatch at its REAL size, so routing sees true
    load under ragged request sizes."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = _compiled("int8")
    with pytest.raises(AssertionError, match="admit_rows"):
        ResNetFrontend(CFG, params, mode="int8", n_replicas=2,
                       microbatch=MB, admit_rows=0)
    fe = ResNetFrontend(CFG, params, mode="int8", n_replicas=1,
                        n_stages=2, microbatch=MB)
    eng = fe.replicas[0]
    eng.submit(FrontendRequest(rid=0, images=_images(1)))  # 1 row, mb=2
    assert eng.pending_rows == 1
    eng.step()                                 # now in flight, stage 0
    assert eng.pending_rows == 1               # exact, not rounded to mb
    while eng.step():
        pass
    assert eng.pending_rows == 0


def test_submit_validation_rejects_malformed(monkeypatch):
    """The front door rejects wrong-rank, wrong-geometry, non-castable,
    and non-finite image payloads with a clear ValueError — mirroring
    ServingEngine.submit's hardening — instead of shape-erroring deep
    inside a packed microbatch (where the crash would also take down the
    innocent requests sharing it).  Nothing malformed enters the queue."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=1,
                        microbatch=MB)
    hw = CFG.in_hw
    bad = [
        (np.zeros((2, hw, hw), np.float32), "shape"),          # rank 3
        (np.zeros((2, hw, hw, 1), np.float32), "shape"),       # 1 channel
        (np.zeros((2, hw + 1, hw + 1, 3), np.float32), "shape"),
        (np.zeros((2, hw, hw, 3, 1), np.float32), "shape"),    # rank 5
        (np.asarray([["nope"]], dtype=object), "castable"),
        (np.full((1, hw, hw, 3), np.nan, np.float32), "NaN/Inf"),
        (np.full((1, hw, hw, 3), np.inf, np.float32), "NaN/Inf"),
    ]
    for images, match in bad:
        with pytest.raises(ValueError, match=match):
            fe.submit(FrontendRequest(rid=99, images=images))
    assert len(fe.queue) == 0 and not fe._inflight
    # a list-of-lists payload that IS castable to the right shape passes
    ok = FrontendRequest(rid=1, images=_images(1).tolist())
    fe.run([ok])
    assert ok.done and isinstance(ok.images, np.ndarray)
    np.testing.assert_array_equal(ok.logits, _reference("int8", ok.images,
                                                        MB))


def test_resubmit_live_request_and_duplicate_rid_rejected(monkeypatch):
    """Re-submitting a request object that is still queued/in-flight, or
    a second request reusing a live rid, used to silently reset the
    victim's dispatch accounting mid-flight — both now raise a clear
    ValueError and leave the fleet untouched.  Once the original request
    completes, both its object and its rid are reusable again."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=1,
                        microbatch=MB)
    req = FrontendRequest(rid=7, images=_images(6))    # 3 microbatches
    fe.submit(req)                                 # queued, not yet run
    with pytest.raises(ValueError, match="already queued or in flight"):
        fe.submit(req)
    with pytest.raises(ValueError, match="duplicates a live request"):
        fe.submit(FrontendRequest(rid=7, images=_images(2, seed=9)))
    assert len(fe.queue) == 1                      # victim untouched
    fe.step()                                      # req now mid-flight
    assert not req.done and req.rows_done < len(req.images)
    with pytest.raises(ValueError, match="already queued or in flight"):
        fe.submit(req)
    while fe.step():
        pass
    assert req.done
    np.testing.assert_array_equal(req.logits, _reference("int8", req.images,
                                                         MB))
    # drained: the same object and the same rid are both legal again
    fe.run([req])
    assert req.done
    other = FrontendRequest(rid=7, images=_images(1, seed=3))
    fe.run([other])
    assert other.done


def test_percentile_edge_cases():
    """The stack's one percentile implementation: None on empty (a fleet
    that served nothing has no p95, not a p95 of 0), identity on a
    single sample, exact interpolation between two."""
    assert _percentile([], 50) is None
    assert _percentile([], 95) is None
    assert _percentile(iter(()), 99) is None       # any empty iterable
    for q in (0, 50, 95, 100):
        assert _percentile([0.25], q) == 0.25
    assert _percentile([1.0, 3.0], 50) == 2.0
    assert _percentile([1.0, 3.0], 0) == 1.0
    assert _percentile([1.0, 3.0], 100) == 3.0
    assert _percentile((3.0, 1.0, 2.0), 95) == pytest.approx(2.9)


def test_latency_reservoir_edge_cases():
    """The bounded latency store: empty -> no percentiles, window
    exactly full keeps everything in arrival order, overflow evicts the
    OLDEST sample first (sliding window, not a random reservoir)."""
    r = Reservoir("lat", window=3)
    assert len(r) == 0 and r.percentile(50) is None
    assert r.snapshot()["p95"] is None and r.observed == 0
    r.observe(5.0)                                 # single sample
    assert r.percentile(50) == 5.0 == r.percentile(95)
    r.append(1.0)                                  # deque-compatible alias
    r.observe(3.0)                                 # window exactly full
    assert len(r) == r.window == 3
    assert r.values() == [5.0, 1.0, 3.0]           # arrival order kept
    assert r.percentile(50) == 3.0
    r.observe(2.0)                                 # overflow: 5.0 evicted
    assert len(r) == 3 and r.observed == 4
    assert r.values() == [1.0, 3.0, 2.0]
    assert r.percentile(100) == 3.0                # max is of the window
    with pytest.raises(AssertionError):
        Reservoir("bad", window=0)


def test_reset_stats_audit_is_structural(monkeypatch):
    """Regression guard for the reset_stats surface: every wave-scoped
    metric the door registers must zero on reset (checked from the
    registry's own scope declarations, so a future counter added without
    a scope decision fails HERE, not in a stale hand-kept list)."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8",
                        n_replicas=2, microbatch=MB)
    fe.run([FrontendRequest(rid=i, images=_images(2, seed=i))
            for i in range(4)])
    assert fe.metrics.wave_names(), "door must register wave metrics"
    fe.reset_stats()
    snap = fe.snapshot()["door"]
    for name in fe.metrics.wave_names():
        kind = fe.metrics.get(name).kind
        if kind == "counter":
            assert snap[name] == 0, name
        elif kind == "reservoir":
            assert snap[name]["count"] == 0, name
        elif kind in ("gauge", "highwater"):
            # queue depth is re-observed on the (drained) queue
            assert snap[name] == 0, name
    # the life side survives: the EWMA row time keeps its calibration
    assert fe.stats()["est_row_time_s"] is not None


def test_latency_window_bounds_samples(monkeypatch):
    """The latency reservoir is a bounded deque: an open-loop serve that
    completes requests forever holds at most ``latency_window`` samples
    (stats() reports the bound and the current fill), and the p50/p95
    reflect only the most recent window."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=1,
                        microbatch=MB, latency_window=4)
    for i in range(8):
        fe.run([FrontendRequest(rid=i, images=_images(1, seed=i))])
    st = fe.stats()
    assert st["requests_done"] == 8                # all completed...
    assert st["latency_samples"] == 4              # ...window kept 4
    assert st["latency_window"] == 4
    assert len(fe._latencies) == 4
    assert st["latency_p95_s"] >= st["latency_p50_s"] > 0
    with pytest.raises(AssertionError):
        ResNetFrontend(CFG, _compiled("int8"), mode="int8",
                       latency_window=0)


def test_two_small_requests_share_a_microbatch(monkeypatch):
    """The continuous-batching demonstrator: two 1-row requests on one
    replica ride in ONE shared microbatch (occupancy 1.0, one injection)
    and each still matches its own single-request reference bit for bit.
    The whole-request baseline (continuous=False) needs two half-empty
    microbatches for the same traffic."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    reqs = [FrontendRequest(rid=i, images=_images(1, seed=i))
            for i in range(2)]
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=1,
                        n_stages=1, microbatch=MB)
    fe.run(reqs)
    _check_vs_reference(reqs, "int8")
    st = fe.replicas[0].stats()
    assert st["mb_injected"] == 1 and st["rows_injected"] == 2
    assert st["microbatch_occupancy"] == 1.0
    base = ResNetFrontend(CFG, _compiled("int8"), mode="int8",
                          n_replicas=1, n_stages=1, microbatch=MB,
                          continuous=False)
    breqs = [FrontendRequest(rid=i, images=_images(1, seed=i))
             for i in range(2)]
    base.run(breqs)
    _check_vs_reference(breqs, "int8")
    stb = base.replicas[0].stats()
    assert stb["mb_injected"] == 2
    assert stb["microbatch_occupancy"] == 0.5


def test_row_granular_dispatch_splits_across_replicas(monkeypatch):
    """A request larger than one replica's admission room spills its
    remaining rows to the other replica instead of head-of-line blocking
    the queue — and the reassembled logits still match the reference."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=2,
                        n_stages=1, microbatch=MB, admit_rows=2)
    req = FrontendRequest(rid=0, images=_images(6))
    fe.run([req])
    _check_vs_reference([req], "int8")
    assert req.replica == 0                    # first rows' replica
    st = fe.stats()
    assert sum(st["rows_dispatched"]) == 6
    assert all(n > 0 for n in st["rows_dispatched"])   # genuinely split


def test_dispatch_load_counters_match_scan(monkeypatch):
    """The O(1) incremental ``pending_rows`` the router reads must equal
    the linear-scan oracle on every replica at every step of a loaded
    mixed-size workload (the scan is what the incremental counters
    replaced to stop dispatch being O(requests²))."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=2,
                        n_stages=2, microbatch=MB, admit_rows=3)
    reqs = [FrontendRequest(rid=i, images=_images(1 + i % 4, seed=i))
            for i in range(8)]
    for r in reqs:
        fe.submit(r)
    while True:
        busy = fe.step()
        for eng in fe.replicas:
            assert eng.pending_rows == eng._scan_pending_rows()
        if not busy:
            break
    _check_vs_reference(reqs, "int8")
    assert all(eng.pending_rows == 0 for eng in fe.replicas)


def test_stats_latency_and_replica_accounting(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled("int8"), mode="int8", n_replicas=2,
                        n_stages=2, microbatch=MB)
    reqs = [FrontendRequest(rid=i, images=_images(2, seed=i))
            for i in range(4)]
    fe.run(reqs)
    st = fe.stats()
    assert st["n_replicas"] == 2
    assert len(st["replica_bubble"]) == 2
    assert len(st["replicas"]) == 2
    assert [s["replica"] for s in st["replicas"]] == [0, 1]
    assert all(s["in_flight"] == 0 for s in st["replicas"])
    assert st["latency_p50_s"] is not None
    assert st["latency_p95_s"] >= st["latency_p50_s"] > 0
    assert all(r.latency_s > 0 for r in reqs)
    assert sum(st["rows_dispatched"]) == 8
    fe.reset_stats()
    assert fe.stats()["requests_done"] == 0
    assert fe.stats()["latency_p50_s"] is None


# ---------------------------------------------------------------------------
# Multi-device harness (forced 4-device CPU fan-out, subprocess)
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import jax, numpy as np, jax.numpy as jnp
from repro import nn
from repro.core.compiled_linear import compile_params
from repro.models import resnet
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.pipeline import reference_logits

assert len(jax.devices()) == 4, jax.devices()
cfg = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
params = nn.unbox(compile_params(resnet.init(jax.random.PRNGKey(0), cfg),
                                 mode="int8"))
fe = ResNetFrontend(cfg, params, mode="int8", n_replicas=2, n_stages=2,
                    microbatch=1)
groups = [[str(s.device) for s in eng.pipe.stages] for eng in fe.replicas]
flat = [d for g in groups for d in g]
assert len(set(flat)) == 4, groups            # disjoint device groups
for eng in fe.replicas:                       # weights live on-group
    for s in eng.pipe.stages:
        for leaf in jax.tree.leaves(s.params):
            assert list(leaf.devices())[0] == s.device, (s.index,
                                                         leaf.devices())
x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3)))
reqs = [FrontendRequest(rid=0, images=x[:1]),
        FrontendRequest(rid=1, images=x[1:4])]
fe.run(reqs)
for r in reqs:
    ref = reference_logits(params, cfg, jnp.asarray(r.images), 1)
    np.testing.assert_array_equal(np.asarray(r.logits), np.asarray(ref))
assert sorted(r.replica for r in reqs) == [0, 1]
print("FLEET_MULTIDEV_OK", groups)
"""


def test_fleet_on_four_forced_devices():
    """Real multi-device fleet: 2 replicas x 2 stages on 4 distinct CPU
    devices, stage params committed to their own group's devices, outputs
    bit-identical per request.  Subprocess because device count is fixed
    at backend init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4")
    env["REPRO_PALLAS"] = "jnp"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                          capture_output=True, text=True, timeout=300,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "FLEET_MULTIDEV_OK" in proc.stdout

"""Bitmap-native sparse conv: packed weights reach the kernel (no op-
boundary expansion), bit-identity vs the dense-expanded conv across the
GEOMS x SIZES sweep, the K%8 pad+mask compile fix (7x7 stem, K=147), and
amax/quant_out parity across lowerings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.kernels import ops, ref
from test_conv import GEOMS, SIZES


def _sparse_conv_leaf(C, n_out, k, stride, sparsity=0.8, seed=0):
    key = jax.random.PRNGKey(seed + 31 * k + C)
    p = {"w": nn.conv_param(key, C, n_out, k, stride,
                            ("conv_in", "conv_out"))}
    packed = nn.unbox(cl.compile_params(p, mode="sparse_cfmm",
                                        sparsity=sparsity))
    return packed["w"]


def _x(C, H, W, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (2, H, W, C),
                              -127, 128, jnp.int8)


@pytest.mark.parametrize("k,stride", GEOMS)
@pytest.mark.parametrize("H,W", SIZES)
def test_sparse_conv_bit_identical_to_dense_expanded(k, stride, H, W):
    """Acceptance sweep: the packed-weight conv (interpret-mode Pallas
    kernel) equals the dense-expanded-codes conv bit for bit — both the
    per-tap expand path (c_in % 8 == 0) and the one-shot slab path."""
    for C in (8, 3):                   # byte-aligned taps / straddling taps
        w = _sparse_conv_leaf(C, 16, k, stride, seed=H + W)
        x = _x(C, H, W)
        y_sp = cl.apply_conv(w, x, 0.02, relu=False)
        codes = cl.packed_codes(w)     # dense channel-major, pad stripped
        y_dn = ops.conv2d(x, codes, k, stride, x_scale=0.02,
                          w_scale=w["scale"].reshape(-1), relu=False)
        np.testing.assert_array_equal(np.asarray(y_sp), np.asarray(y_dn))


@pytest.mark.parametrize("k,stride", [(3, 1), (7, 2)])
def test_sparse_conv_kernel_vs_jnp_oracle_exact(k, stride):
    """conv2d_sparse_pallas (interpret) == the bitmap-native jnp oracle,
    exactly, with the full Collector epilogue fused."""
    C, n_out = 8, 16
    w = _sparse_conv_leaf(C, n_out, k, stride)
    x = _x(C, 9, 7)
    key = jax.random.PRNGKey(5)
    gamma = jax.random.normal(key, (n_out,))
    beta = jax.random.normal(jax.random.fold_in(key, 1), (n_out,))
    h_out, w_out = -(-9 // stride), -(-7 // stride)
    sc = jax.random.normal(jax.random.fold_in(key, 2),
                           (2, h_out, w_out, n_out))
    y = ops.conv2d(x, (w["bitmap"], w["values"]), k, stride, x_scale=0.03,
                   w_scale=w["scale"].reshape(-1), gamma=gamma, beta=beta,
                   shortcut=sc, relu=True)
    eff_scale = 0.03 * w["scale"].reshape(-1) * gamma
    want = ref.conv2d_sparse_collector_ref(
        x, w["bitmap"], w["values"], k, stride, eff_scale, beta, sc, True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_stem_k147_compiles_to_bitmap():
    """Regression for the silent dense fallback: ResNet50's 7x7 stem has
    K = 3*49 = 147; sparse_cfmm must pad+mask to 152 and carry a bitmap
    key, and the packed forward must match the pruned-dense reference."""
    w = _sparse_conv_leaf(3, 64, 7, 2)
    assert set(w) == {"bitmap", "values", "scale", "geom"}
    assert w["bitmap"].shape == (19, 64)           # ceil(147/8) = 19 rows
    codes = cl.packed_codes(w)
    assert codes.shape == (147, 64)                # pad stripped
    x = _x(3, 16, 16)
    y_sp = cl.apply_conv(w, x, 0.05, relu=True)
    y_dn = ops.conv2d(x, codes, 7, 2, x_scale=0.05,
                      w_scale=w["scale"].reshape(-1), relu=True)
    np.testing.assert_array_equal(np.asarray(y_sp), np.asarray(y_dn))


def test_linear_k_off_boundary_compiles_to_bitmap():
    """The pad+mask fix covers linear leaves too: K % 8 != 0 packs (rows =
    ceil(K/8)) instead of falling back to dense int8, and the kernel pads
    activations with exact zero columns."""
    key = jax.random.PRNGKey(3)
    p = {"w": nn.Param(jax.random.normal(key, (147, 64)) * 0.05,
                       ("embed", "ffn_in"), "linear")}
    packed = nn.unbox(cl.compile_params(p, mode="sparse_cfmm",
                                        sparsity=0.8))
    assert set(packed["w"]) == {"bitmap", "values", "scale", "kdim"}
    assert packed["w"]["bitmap"].shape == (19, 64)
    # the KDim marker keeps the packed_codes/dense_of shape contract: the
    # pad_rows8 rows are stripped, algebraic consumers see the true K
    codes = cl.packed_codes(packed["w"])
    assert codes.shape == (147, 64)
    assert cl.dense_of(packed["w"]).shape == (147, 64)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 147))
    y = cl.apply_linear(packed["w"], x)
    x_q, s_x = cl.act_quant(x)
    want = (ref.int8_matmul_ref(x_q, codes)
            .astype(jnp.float32) * (s_x * packed["w"]["scale"]))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("packed", [False, True])
def test_quant_out_amax_parity_across_lowerings(monkeypatch, packed):
    """The on-chip epilogue amax (interpret mode) yields the same s_y as
    the jnp max(abs(y)) path, so the int8 activations handed to the next
    block are identical across lowerings — for dense codes and for the
    bitmap-native sparse path."""
    C, n_out, k, stride = 8, 16, 3, 2
    w = _sparse_conv_leaf(C, n_out, k, stride)
    codes = (w["bitmap"], w["values"]) if packed else cl.packed_codes(w)
    x = _x(C, 9, 7)
    outs = {}
    for mode in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        outs[mode] = ops.conv2d(x, codes, k, stride, x_scale=0.02,
                                w_scale=w["scale"].reshape(-1),
                                gamma=jnp.ones((n_out,)),
                                beta=jnp.zeros((n_out,)), relu=True,
                                quant_out=True)
    np.testing.assert_array_equal(np.asarray(outs["jnp"][0]),
                                  np.asarray(outs["interpret"][0]))
    np.testing.assert_array_equal(np.asarray(outs["jnp"][1]),
                                  np.asarray(outs["interpret"][1]))


def test_serving_hot_path_never_expands(monkeypatch):
    """Packed weights reach the kernel: zero calls to bitmap_unpack /
    bitmap_expand_ref while serving a sparse conv in either lowering (the
    in-kernel expand is kernels.bitmap.expand_bitmap_tile, VMEM-only)."""
    calls = {"n": 0}
    real_unpack = cl.bitmap_unpack
    real_expand = ref.bitmap_expand_ref

    def spy_unpack(*a, **kw):
        calls["n"] += 1
        return real_unpack(*a, **kw)

    def spy_expand(*a, **kw):
        calls["n"] += 1
        return real_expand(*a, **kw)

    monkeypatch.setattr(cl, "bitmap_unpack", spy_unpack)
    monkeypatch.setattr(ref, "bitmap_expand_ref", spy_expand)
    w = _sparse_conv_leaf(8, 16, 3, 1)
    x = _x(8, 8, 8)
    for mode in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        y_q, s_y = cl.apply_conv(w, x, 0.02, quant_out=True)
        assert y_q.dtype == jnp.int8
    assert calls["n"] == 0


def test_expand_tile_chunked_matches_unpack():
    """The shared expand tile, streamed in chunks with a carried nonzero
    count (exactly what both sparse kernels do), reproduces the one-shot
    bitmap_unpack."""
    from repro.kernels.bitmap import expand_bitmap_tile
    key = jax.random.PRNGKey(9)
    K, N, keep = 96, 16, 24
    qt = cl.balanced_prune_codes(jax.random.normal(key, (K, N)), keep)
    bitmap, values = cl.bitmap_pack(qt.values, keep)
    want = cl.bitmap_unpack(bitmap, values)
    for rows8 in (1, 3, 12):           # 8-, 24-, 96-row chunks
        base = jnp.zeros((1, N), jnp.int32)
        got = []
        for c in range(0, K // 8, rows8):
            w_c, base = expand_bitmap_tile(bitmap[c:c + rows8], values,
                                           base, keep)
            got.append(w_c)
        np.testing.assert_array_equal(np.asarray(jnp.concatenate(got)),
                                      np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(base), np.asarray((qt.values != 0).sum(0)[None, :]))

"""Paper-reproduction assertions: Table I exact, Table II structural,
Fig 7 properties."""
import numpy as np
import pytest

from repro.core import fpga_model as fm
from repro.core import partition
from repro.models import resnet


def test_table1_exact():
    t1 = resnet.table1()
    assert t1["conv2_x"] == dict(channel_count="64/256", hw="56x56",
                                 param_count_k=70, total_macs_m=218,
                                 mac_per_param=3136)
    assert t1["conv3_x"]["param_count_k"] == 279
    assert t1["conv4_x"]["param_count_k"] == 1114
    assert t1["conv5_x"]["param_count_k"] == 4456
    assert [r["mac_per_param"] for r in t1.values()] == [3136, 784, 196, 49]
    assert all(r["total_macs_m"] == 218 for r in t1.values())


def test_cfmm_constants():
    assert fm.UNIQUE_PRODUCTS == 32
    assert fm.SPARSITY == 0.80


def test_table2_reproduces_design_decisions():
    t2 = fm.table2_model()
    # conv5 must fold 4x to fit GX280 (paper SS III.1)
    assert t2["conv5"]["model"]["fold"] == 4
    # conv5 kernel ~620k ALMs (2x CFMM dupes)
    assert abs(t2["conv5"]["model"]["alm_per_kernel"] - 620_000) / 620_000 < 0.05
    # conv2 4-instance kernel calibrated at 127k ALMs
    assert abs(t2["conv2"]["model"]["alm_per_kernel"] - 127_000) / 127_000 < 0.01
    # conv2 needs ~8 instances to match throughput (paper: 8; model: 7-8)
    assert t2["conv2"]["model"]["instances_total"] in (7, 8)
    # corner frequencies track the measured 353 / 156 MHz
    assert abs(t2["conv2"]["model"]["freq_mhz"] - 353) < 5
    assert abs(t2["conv5"]["model"]["freq_mhz"] - 156) < 8
    # throughput-density within the model's honesty band of actuals
    for c in ("conv2", "conv5"):
        ratio = (t2[c]["model"]["mops_per_alm"]
                 / t2[c]["actual"]["mops_per_alm"])
        assert 0.5 < ratio < 1.6, (c, ratio)


def test_fig7_partition_properties():
    blocks = resnet.resnet50_conv_blocks()
    res = partition.solve_max_throughput(blocks, max_link_gbps=75.0)
    assert res.max_link_gbps <= 75.0 + 1e-6          # link budget respected
    assert all(c.utilization(res.spec) <= 0.78 for c in res.chips)
    assert res.achieved_im_s > 0
    # every ResNet50 conv layer is placed exactly once
    placed = [l["layer"] for c in res.chips for l in c.layers]
    want = [l.name for blk in blocks for l in blk]
    assert sorted(placed) == sorted(want)


def test_freq_model_interpolates_corners():
    assert abs(fm.freq_model(127_000) - 353) < 1
    assert abs(fm.freq_model(620_000) - 156) < 1
    assert fm.freq_model(300_000) < 353
    assert fm.freq_model(300_000) > 156


def test_serial_cycles_monotone_in_fanin():
    small = fm.ConvLayerSpec("s", 64, 64, 3, 56)
    big = fm.ConvLayerSpec("b", 512, 512, 3, 7)
    assert fm.serial_cycles(big) > fm.serial_cycles(small) > fm.ACT_BITS


def test_lm_pipeline_partitioner_balances():
    from repro.core.partition import partition_lm
    from repro.configs.base import get_config
    for arch in ("phi3_medium_14b", "jamba_v01_52b", "deepseek_v2_lite_16b"):
        cfg = get_config(arch)
        plan = partition_lm(cfg, n_stages=4, batch=128)
        assert plan["n_stages"] == 4
        assert sum(plan["layers_per_stage"]) == cfg.n_layers
        assert plan["balance"] > 0.5, (arch, plan)

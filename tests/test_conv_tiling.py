"""Row-strip tiled conv: strip-boundary bit-identity property sweep
(tiled vs untiled, jnp and interpret lowerings, dense and bitmap-packed),
the strip planner's budget/halo arithmetic, and the quantization-domain
scale from the strip-reduced amax."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.core.quantize import quantize_int7
from repro.kernels import ops, ref
from repro.kernels.tiling import plan_strips, strip_geometry

# the acceptance grid: k x stride x odd/even H x strip_h that does not
# divide h_out (plus dividing ones), covering halo rows, stride-2
# subsampled strips, and the k=7 s=2 stem corner
KS = [(1, 1), (1, 2), (3, 1), (3, 2), (7, 1), (7, 2)]
HS = [8, 9]
STRIP_HS = [1, 3, 4]


def _conv_case(k, H, W, C, n_out=16, seed=0):
    key = jax.random.PRNGKey(seed + 17 * k + H + C)
    x = jax.random.randint(key, (2, H, W, C), -127, 128, jnp.int8)
    w = jax.random.normal(jax.random.fold_in(key, 1),
                          (C * k * k, n_out)) * 0.1
    return x, quantize_int7(w)


@pytest.mark.parametrize("k,stride", KS)
@pytest.mark.parametrize("H", HS)
@pytest.mark.parametrize("strip_h", STRIP_HS)
def test_tiled_bit_identical_dense(k, stride, H, strip_h, monkeypatch):
    """Dense codes: the strip-tiled conv equals the untiled conv bit for
    bit in BOTH lowerings, and the interpret kernel equals the jnp
    oracle exactly (unit scales keep the f32 epilogue integer-exact)."""
    x, qt = _conv_case(k, H, 7, C=8)
    n_out = qt.values.shape[1]
    kw = dict(x_scale=1.0, w_scale=jnp.ones((n_out,)), relu=False)
    outs = {}
    for mode in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        outs[mode, "untiled"] = ops.conv2d(x, qt.values, k, stride, **kw)
        outs[mode, "tiled"] = ops.conv2d(x, qt.values, k, stride,
                                         strip_h=strip_h, **kw)
    want = np.asarray(outs["jnp", "untiled"])
    for key_ in outs:
        np.testing.assert_array_equal(np.asarray(outs[key_]), want)


@pytest.mark.parametrize("k,stride", [(3, 1), (3, 2), (7, 2)])
@pytest.mark.parametrize("strip_h", [1, 3])
def test_tiled_bit_identical_bitmap_packed(k, stride, strip_h, monkeypatch):
    """Bitmap-packed weights ride the same strip decomposition: tiled ==
    untiled bit-for-bit in both lowerings, for byte-aligned (c_in=8) and
    tap-straddling (c_in=3, the stem) packings."""
    for C in (8, 3):
        key = jax.random.PRNGKey(5 * k + C)
        p = {"w": nn.conv_param(key, C, 16, k, stride,
                                ("conv_in", "conv_out"))}
        w = nn.unbox(cl.compile_params(p, mode="sparse_cfmm",
                                       sparsity=0.8))["w"]
        x = jax.random.randint(jax.random.fold_in(key, 1), (2, 9, 7, C),
                               -127, 128, jnp.int8)
        codes = (w["bitmap"], w["values"])
        kw = dict(x_scale=0.02, w_scale=w["scale"].reshape(-1), relu=False)
        outs = {}
        for mode in ("jnp", "interpret"):
            monkeypatch.setenv("REPRO_PALLAS", mode)
            outs[mode, "u"] = ops.conv2d(x, codes, k, stride, **kw)
            outs[mode, "t"] = ops.conv2d(x, codes, k, stride,
                                         strip_h=strip_h, **kw)
        want = np.asarray(outs["jnp", "u"])
        for key_ in outs:
            np.testing.assert_array_equal(np.asarray(outs[key_]), want)


def test_stem_geometry_tiled(monkeypatch):
    """The 224x224-class stem corner at test scale: k=7 s=2 c_in=3 with a
    strip_h that does not divide h_out — tiled == untiled in both
    lowerings, including the quant_out scale from the strip-reduced
    amax (the last strip's surplus rows must not leak into it)."""
    k, stride, C, n_out = 7, 2, 3, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (1, 20, 20, C), -127, 128, jnp.int8)
    w = jax.random.normal(jax.random.fold_in(key, 1),
                          (C * k * k, n_out)) * 0.1
    qt = quantize_int7(w)
    kw = dict(x_scale=0.02, w_scale=qt.scale.reshape(-1),
              gamma=jnp.ones((n_out,)), beta=jnp.full((n_out,), 0.3),
              relu=True, quant_out=True)
    outs = {}
    for mode in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        outs[mode, "u"] = ops.conv2d(x, qt.values, k, stride, **kw)
        outs[mode, "t"] = ops.conv2d(x, qt.values, k, stride, strip_h=3,
                                     **kw)  # h_out=10, 4 strips, last short
    yu, su = outs["jnp", "u"]
    for key_ in outs:
        y, s = outs[key_]
        np.testing.assert_array_equal(np.asarray(y), np.asarray(yu))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(su))


def test_tiled_shortcut_and_collector(monkeypatch):
    """Shortcut adds land in the right strip rows (the strip-blocked
    re-layout) and the fused Collector matches the untiled epilogue."""
    k, stride, n_out = 3, 1, 16
    x, qt = _conv_case(k, 9, 7, C=8)
    key = jax.random.PRNGKey(7)
    sc = jax.random.normal(key, (2, 9, 7, n_out))
    gamma = jax.random.normal(jax.random.fold_in(key, 1), (n_out,))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (n_out,))
    kw = dict(x_scale=0.03, w_scale=qt.scale.reshape(-1), gamma=gamma,
              beta=beta, shortcut=sc, relu=True)
    for mode in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        y_u = ops.conv2d(x, qt.values, k, stride, **kw)
        y_t = ops.conv2d(x, qt.values, k, stride, strip_h=4, **kw)
        np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_u))


# ---------------------------------------------------------------------------
# Strip planner
# ---------------------------------------------------------------------------

def test_strip_geometry_halo_math():
    """slab_h = (strip_h-1)*stride + k, strips advance strip_h*stride
    input rows, and x_rows covers the last strip's slab."""
    g = strip_geometry(k=3, stride=1, h_out=10, w_out=10, strip_h=4)
    assert (g.n_strips, g.slab_h, g.row_step) == (3, 6, 4)
    assert g.x_rows == 2 * 4 + 6                       # last slab in bounds
    assert (g.ms, g.ms_pad) == (40, 40)
    g = strip_geometry(k=7, stride=2, h_out=112, w_out=112, strip_h=5)
    assert (g.slab_h, g.row_step) == (4 * 2 + 7, 10)   # k-stride halo = 5
    assert g.n_strips == -(-112 // 5)
    # degenerate: one strip == the untiled kernel's whole-image residency
    g1 = strip_geometry(k=3, stride=1, h_out=7, w_out=7, strip_h=7)
    assert (g1.n_strips, g1.slab_h) == (1, 9)


def test_plan_strips_budget():
    """The planner maximizes strip_h under the budget, degenerates to one
    strip for small maps, and floors at single-row strips."""
    small = plan_strips(k=3, stride=1, h_out=7, w_out=7, wp=9, c_in=512,
                        bn=128, weight_bytes=9 * 512 * 128)
    assert small.n_strips == 1                         # conv5_x fits whole
    big = plan_strips(k=7, stride=2, h_out=112, w_out=112, wp=229, c_in=3,
                      bn=64, weight_bytes=7 * 7 * 3 * 64)
    assert big.n_strips > 1 and big.cell_bytes <= 1 << 20
    bigger = plan_strips(k=7, stride=2, h_out=112, w_out=112, wp=229,
                         c_in=3, bn=64, weight_bytes=7 * 7 * 3 * 64,
                         budget=big.cell_bytes + (1 << 16))
    assert bigger.strip_h >= big.strip_h               # monotone in budget
    floor = plan_strips(k=3, stride=1, h_out=64, w_out=64, wp=66, c_in=64,
                        bn=128, weight_bytes=9 * 64 * 128, budget=1)
    assert floor.strip_h == 1
    forced = plan_strips(k=3, stride=1, h_out=10, w_out=10, wp=12, c_in=8,
                         bn=16, weight_bytes=9 * 8 * 16, strip_h=4)
    assert (forced.strip_h, forced.n_strips) == (4, 3)


def test_planner_default_untouched_for_resnet50_geoms(monkeypatch):
    """With no override, ResNet50-sized test geometries plan a single
    strip under the default budget, so the serving path is byte-for-byte
    the pre-tiling launch (and the compiled ResNet keeps matching the
    dense path end to end, see test_conv.py)."""
    p = plan_strips(k=3, stride=1, h_out=14, w_out=14, wp=16, c_in=64,
                    bn=128, weight_bytes=9 * 64 * 128)
    assert p.n_strips == 1

import numpy as np

from repro.configs.base import get_config
from repro.roofline import analysis as ra
from repro.roofline import analytic

HLO_SAMPLE = """
  %ag = bf16[256,1024]{1,0} all-gather(bf16[16,1024]{1,0} %p0), dimensions={0}
  %ar.1 = f32[4096]{0} all-reduce(f32[4096]{0} %x), to_apply=%add
  tuple = (f32[128,64]{1,0}, f32[128,64]{1,0}) all-to-all(f32[128,64] %a, f32[128,64] %b)
  %rs = bf16[8,512]{1,0} reduce-scatter(bf16[64,512]{1,0} %y), dimensions={0}
  %cp = u8[1024]{0} collective-permute(u8[1024]{0} %z)
  %dot = f32[12,12] dot(f32[12,4] %l, f32[4,12] %r)
"""


def test_parse_collectives():
    out = ra.parse_collectives(HLO_SAMPLE)
    assert out["all-gather"]["bytes"] == 256 * 1024 * 2
    assert out["all-reduce"]["bytes"] == 4096 * 4
    assert out["all-reduce"]["wire_bytes"] == 2 * 4096 * 4   # ring 2x
    assert out["all-to-all"]["bytes"] == 2 * 128 * 64 * 4    # tuple out
    assert out["reduce-scatter"]["bytes"] == 8 * 512 * 2
    assert out["collective-permute"]["bytes"] == 1024
    assert "dot" not in out


def test_roofline_terms_and_dominance():
    r = ra.Roofline(flops=197e12 * 0.01, hbm_bytes=819e9 * 0.02,
                    wire_bytes=50e9 * 0.005, chips=256,
                    model_flops=197e12 * 0.01 * 256 * 0.5)
    assert abs(r.compute_s - 0.01) < 1e-9
    assert abs(r.memory_s - 0.02) < 1e-9
    assert abs(r.collective_s - 0.005) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.useful_flops_fraction - 0.5) < 1e-9
    assert abs(r.roofline_fraction - 0.25) < 1e-9   # 0.5*compute / memory


def test_analytic_decode_weight_traffic_ordering():
    """The paper's packing must strictly reduce the decode weight term."""
    cfg = get_config("phi3_medium_14b")
    mesh = {"data": 16, "model": 16}
    n = 14_000_000_000
    w, tot = {}, {}
    for mode in ("dense", "int8", "sparse_cfmm"):
        m = analytic.model_cell(cfg, "decode_32k", mesh, n, n, mode)
        w[mode] = m.breakdown["weight_bytes_dev"]
        tot[mode] = m.hbm_device
    assert w["sparse_cfmm"] < w["int8"] < w["dense"]
    assert w["dense"] / w["sparse_cfmm"] > 5.0
    # phi3's kv=10 heads don't divide the 16-way model axis -> the cache
    # replicates and dominates; split-KV sharding recovers the win
    m_split = analytic.model_cell(cfg, "decode_32k", mesh, n, n,
                                  "sparse_cfmm", rules_name="serve_splitkv")
    assert m_split.hbm_device < 0.2 * tot["sparse_cfmm"]


def test_analytic_train_is_not_collective_dominated_single_pod():
    cfg = get_config("smollm_360m")
    mesh = {"data": 16, "model": 16}
    m = analytic.model_cell(cfg, "train_4k", mesh, 362_000_000, 362_000_000)
    assert m.flops_device > 0 and m.hbm_device > 0 and m.wire_device > 0


def test_active_params_moe():
    cfg = get_config("olmoe_1b_7b")
    total = 7_000_000_000
    active = ra.active_param_count(cfg, total)
    assert active < total * 0.35   # 8 of 64 experts active

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import quantize as q

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 2**31 - 1), st.integers(4, 64), st.integers(2, 48))
def test_int7_roundtrip_error_bound(seed, rows, cols):
    w = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    qt = q.quantize_int7(w, axis=-1)
    assert qt.values.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(qt.values))) <= q.INT7_MAX
    # per-element error bounded by half a quantization step of its channel
    err = jnp.abs(w - qt.dequantize())
    assert bool(jnp.all(err <= 0.5 * qt.scale + 1e-6))


def test_scale_is_per_output_channel():
    w = jnp.array([[1.0, 100.0], [2.0, 50.0]])
    qt = q.quantize_int7(w, axis=-1)
    assert qt.scale.shape == (1, 2)
    np.testing.assert_allclose(np.asarray(qt.scale)[0],
                               [2 / 63, 100 / 63], rtol=1e-6)


@given(st.integers(0, 2**31 - 1))
def test_ternary_residual_exact(seed):
    codes = jax.random.randint(jax.random.PRNGKey(seed), (64,),
                               -q.INT7_MAX, q.INT7_MAX + 1)
    t = q.ternary_residual_decompose(codes)
    assert t.shape == (64, 6)
    assert set(np.unique(np.asarray(t))) <= {-1, 0, 1}
    back = q.ternary_residual_reconstruct(t)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_fake_quant_straight_through_grad():
    w = jnp.linspace(-1.0, 1.0, 32).reshape(4, 8)
    g = jax.grad(lambda x: jnp.sum(q.fake_quant_int7(x)))(w)
    # STE: gradient flows as if identity through round
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.mean(jnp.abs(g))) > 0.5


def test_act_quant_saturates_to_int8():
    x = jnp.array([1e6, -1e6, 0.0])
    at = q.quantize_act_int8(x)
    assert int(jnp.max(jnp.abs(at.values))) <= 127

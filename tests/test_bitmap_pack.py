"""Property-based round-trips for bitmap_pack/bitmap_unpack (the packed
sparse weight format every sparse kernel consumes): random keep_k, K at
and off the %8 boundary (via the pad_rows8 K-padding rule), all-zero
columns, and the keep_k == K dense limit."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import compiled_linear as cl

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _random_codes(seed: int, K: int, N: int, keep_k: int,
                  zero_col_frac: float = 0.0) -> np.ndarray:
    """int8 codes with <= keep_k nonzeros per column (random count and
    row placement), optionally forcing some columns all-zero."""
    rng = np.random.RandomState(seed)
    codes = np.zeros((K, N), np.int8)
    for col in range(N):
        if rng.rand() < zero_col_frac:
            continue                       # all-zero column
        nnz = rng.randint(0, min(keep_k, K) + 1)
        rows = rng.choice(K, size=nnz, replace=False)
        mags = rng.randint(1, 64, size=nnz)
        signs = rng.choice(np.array([-1, 1], np.int64), size=nnz)
        codes[rows, col] = (mags * signs).astype(np.int8)
    return codes


@given(st.integers(0, 10_000),
       st.sampled_from([16, 37, 115, 147, 152, 256]),   # on & off %8
       st.sampled_from([8, 24, 40]),
       st.sampled_from([4, 16]),
       st.floats(0.0, 0.5))
def test_pack_unpack_roundtrip(seed, K, keep_k, N, zero_col_frac):
    codes = _random_codes(seed, K, N, keep_k, zero_col_frac)
    padded = cl.pad_rows8(jnp.asarray(codes))
    assert padded.shape[0] % 8 == 0 and padded.shape[0] - K < 8
    bitmap, values = cl.bitmap_pack(padded, keep_k)
    assert bitmap.shape == (padded.shape[0] // 8, N)
    assert values.shape == (keep_k, N)
    dense = np.asarray(cl.bitmap_unpack(bitmap, values))
    np.testing.assert_array_equal(dense[:K], codes)
    assert (dense[K:] == 0).all()          # masked pad rows stay zero


@given(st.integers(0, 10_000), st.sampled_from([8, 40, 104]),
       st.sampled_from([4, 8]))
def test_dense_limit_keep_k_equals_K(seed, K, N):
    """keep_k == K: every row may be a nonzero — the bitmap format
    degrades gracefully to a dense store plus an all-ones mask."""
    rng = np.random.RandomState(seed)
    codes = rng.randint(-63, 64, size=(K, N)).astype(np.int8)
    codes[0, :] = 1                        # ensure some structure survives
    bitmap, values = cl.bitmap_pack(jnp.asarray(codes), K)
    dense = np.asarray(cl.bitmap_unpack(bitmap, values))
    np.testing.assert_array_equal(dense, codes)
    fully_dense_cols = (codes != 0).all(axis=0)
    bits = np.unpackbits(np.asarray(bitmap), axis=0, bitorder="little")
    np.testing.assert_array_equal(bits.all(axis=0), fully_dense_cols)


def test_all_zero_matrix_roundtrip():
    codes = jnp.zeros((24, 4), jnp.int8)
    bitmap, values = cl.bitmap_pack(codes, 8)
    assert not np.asarray(bitmap).any()
    np.testing.assert_array_equal(np.asarray(cl.bitmap_unpack(bitmap,
                                                              values)),
                                  np.zeros((24, 4), np.int8))


@given(st.integers(0, 10_000), st.sampled_from([9, 31, 147]))
def test_pad_rows8_exact_under_matmul(seed, K):
    """The K-padding rule is exact: padded codes against zero-padded int8
    activations give the same matmul as the unpadded originals."""
    from repro.kernels import ref
    codes = _random_codes(seed, K, 8, keep_k=K)
    x = np.random.RandomState(seed + 1).randint(
        -127, 128, size=(3, K)).astype(np.int8)
    padded = cl.pad_rows8(jnp.asarray(codes))
    xp = jnp.pad(jnp.asarray(x), ((0, 0), (0, padded.shape[0] - K)))
    want = ref.int8_matmul_ref(jnp.asarray(x), jnp.asarray(codes))
    got = ref.int8_matmul_ref(xp, padded)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

"""Deliverable (e) guard: the multi-pod dry-run artifacts must exist for
every (arch x shape x mesh) cell and be internally consistent."""
import json
import pathlib

import pytest

from repro.configs.base import ARCH_IDS, SHAPES, cell_applicable, get_config

ART = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

pytestmark = pytest.mark.skipif(
    not ART.exists(), reason="run `python -m repro.launch.dryrun --all` first")


@pytest.mark.parametrize("mesh_dir,chips", [("single", 256), ("multi", 512)])
def test_all_cells_have_artifacts(mesh_dir, chips):
    missing, failed = [], []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            p = ART / mesh_dir / f"{arch}__{shape}.json"
            if not p.exists():
                missing.append(p.name)
                continue
            d = json.loads(p.read_text())
            if "error" in d:
                failed.append(p.name)
                continue
            ok, why = cell_applicable(get_config(arch), shape)
            if not ok:
                assert d.get("skipped"), p.name
                continue
            assert d["chips"] == chips, p.name
            r = d["roofline"]
            assert r["flops_per_device"] >= 0
            assert r["dominant"] in ("compute", "memory", "collective")
    assert not missing, f"missing artifacts: {missing}"
    assert not failed, f"failed cells: {failed}"


def test_multi_pod_cells_show_pod_axis_traffic():
    """At least the big train cells must communicate across the pod axis
    (gradient all-reduce) — more wire than single-pod."""
    import math
    grew = 0
    checked = 0
    for arch in ("phi3_medium_14b", "jamba_v01_52b", "qwen2_vl_7b"):
        s = json.loads((ART / "single" / f"{arch}__train_4k.json").read_text())
        m = json.loads((ART / "multi" / f"{arch}__train_4k.json").read_text())
        if "roofline" in s and "roofline" in m:
            checked += 1
            if (m["roofline"]["wire_bytes_per_device"]
                    >= s["roofline"]["wire_bytes_per_device"] * 0.99):
                grew += 1
    assert checked and grew >= checked - 1

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import grad_compression as gc


def test_qdq_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    out = gc.compress_decompress({"w": g}, method="int8")["w"]
    rel = float(jnp.linalg.norm(out - g) / jnp.linalg.norm(g))
    assert rel < 0.02


def test_vectors_pass_through_uncompressed():
    b = jnp.ones((16,))
    out = gc.compress_decompress({"b": b}, method="int8")["b"]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(b))


def test_error_feedback_reduces_bias():
    """With feedback, the *accumulated* compressed signal tracks the true
    accumulated gradient much better than independent QDQ."""
    key = jax.random.PRNGKey(1)
    true_acc = jnp.zeros((32, 32))
    fb_acc = jnp.zeros((32, 32))
    plain_acc = jnp.zeros((32, 32))
    errors = gc.init_error_feedback({"w": jax.ShapeDtypeStruct((32, 32),
                                                               jnp.float32)})
    for i in range(20):
        g = jax.random.normal(jax.random.fold_in(key, i), (32, 32)) \
            + 0.05  # small persistent bias that naive QDQ keeps losing
        true_acc = true_acc + g
        comp, errors = gc.compress_with_feedback({"w": g}, errors)
        fb_acc = fb_acc + comp["w"]
        plain_acc = plain_acc + gc.compress_decompress({"w": g})["w"]
    fb_err = float(jnp.linalg.norm(fb_acc - true_acc))
    plain_err = float(jnp.linalg.norm(plain_acc - true_acc))
    assert fb_err <= plain_err * 1.05
    assert fb_err / float(jnp.linalg.norm(true_acc)) < 0.01

"""Graph IR + model zoo (DESIGN.md §12): topological determinism, DAG
cuts, ResNet bit-identity vs the pre-graph hand-rolled units, RepVGG
branch-fusion equivalence, depthwise-vs-oracle agreement, the
graph-derived frontend input geometry, and the expansion config field."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.core import partition
from repro.kernels import ops
from repro.models import mobilenet_v2 as mb
from repro.models import repvgg, resnet
from repro.models.graph import Graph, GraphError, Node, compile_graph

R_CFG = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
M_CFG = mb.MobileNetV2Config(width_mult=0.125, num_classes=4, in_hw=16)
V_CFG = repvgg.RepVGGConfig(width_mult=0.125, num_classes=4, in_hw=16)


# ---------------------------------------------------------------------------
# Graph structure
# ---------------------------------------------------------------------------

def test_topo_order_deterministic_and_stable():
    """Kahn order is insertion-priority deterministic: repeated calls are
    identical, a builder declaring nodes in dataflow order compiles to
    exactly that order, and declaring independent nodes in a different
    order yields the declaration order among ready nodes."""
    g = resnet.resnet_graph(R_CFG)
    order = [n.name for n in g.topo_order()]
    assert order == [n.name for n in g.topo_order()]
    assert order[:5] == ["image", "stem_in", "stem", "stem_pool", "stem_q"]
    # the projection shortcut and the a-conv are both ready after stem_q;
    # the earlier-declared sc runs first
    assert order[5:7] == ["conv2_x_1/sc", "conv2_x_1/a"]
    # permuting two independent declarations flips only their mutual order
    n = {x.name: x for x in g.nodes}
    swapped = list(g.nodes)
    i, j = swapped.index(n["conv2_x_1/sc"]), swapped.index(n["conv2_x_1/a"])
    swapped[i], swapped[j] = swapped[j], swapped[i]
    g2 = Graph(g.name, tuple(swapped), g.in_hw, g.in_ch, g.num_classes)
    assert [x.name for x in g2.topo_order()][5:7] == ["conv2_x_1/a",
                                                      "conv2_x_1/sc"]


def test_graph_validation_errors():
    base = (Node("image", "input"),
            Node("q", "quant", ("image",)),
            Node("c", "conv", ("q",), k=3, c_in=3, c_out=8, quant_out=True),
            Node("head", "head", ("c",)))
    Graph("ok", base, 8, 3, 4).shapes()          # sane baseline
    with pytest.raises(GraphError, match="duplicate"):
        Graph("bad", base + (Node("c", "conv", ("q",)),), 8, 3, 4)
    with pytest.raises(GraphError, match="unknown input"):
        Graph("bad", base[:2] + (Node("c", "conv", ("ghost",)),), 8, 3, 4)
    with pytest.raises(GraphError, match="cycle"):
        Graph("bad", (Node("image", "input"),
                      Node("a", "quant", ("b",)),
                      Node("b", "quant", ("a",))), 8, 3, 4).topo_order()
    with pytest.raises(GraphError, match="c_in"):
        Graph("bad", (Node("image", "input"), Node("q", "quant", ("image",)),
                      Node("c", "conv", ("q",), k=3, c_in=5, c_out=8)),
              8, 3, 4).shapes()
    with pytest.raises(GraphError, match="c_out == c_in"):
        Graph("bad", (Node("image", "input"), Node("q", "quant", ("image",)),
                      Node("c", "dwconv", ("q",), k=3, c_in=3, c_out=8)),
              8, 3, 4).shapes()
    with pytest.raises(GraphError, match="conv consumes"):
        Graph("bad", (Node("image", "input"),
                      Node("c", "conv", ("image",), k=3, c_in=3, c_out=8)),
              8, 3, 4).shapes()
    # a conv past the last quantization-domain cut cannot form a head unit
    with pytest.raises(GraphError, match="conv-free"):
        Graph("bad", (Node("image", "input"), Node("q", "quant", ("image",)),
                      Node("c1", "conv", ("q",), k=3, c_in=3, c_out=8,
                           quant_out=True),
                      Node("c2", "conv", ("c1",), k=3, c_in=8, c_out=8,
                           quant_out=True),
                      Node("head", "head", ("c1",))), 8, 3, 4).units()


def test_resnet_graph_cuts_match_legacy_units(monkeypatch):
    """The articulation cuts land exactly on the old stem/block/head
    boundaries: same unit names, same block ids, one unit per residual
    block."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = nn.unbox(cl.compile_params(R_CFG.init(jax.random.PRNGKey(0)),
                                        mode="int8"))
    units = compile_graph(R_CFG.graph(), params)
    assert [(u.name, u.block_id) for u in units[:2]] == [("stem", 0),
                                                         ("conv2_x_1", 1)]
    assert units[-1].name == "head" and units[-1].block_id == -1
    assert len(units) == 18
    # sparsity aux keys keep the legacy layer names
    punits = compile_graph(R_CFG.graph(), params, sparsity_groups=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 3))
    carry, aux = punits[0].fn(punits[0].params, x)
    assert set(aux) == {"stem"}
    carry, aux = punits[1].fn(punits[1].params, carry)
    assert set(aux) == {"conv2_x_1/a", "conv2_x_1/b", "conv2_x_1/c"}


def test_mobilenet_and_repvgg_cut_structure():
    mg = M_CFG.graph()
    names = [name for name, _ in mg.units()]
    assert names[0] == "stem" and names[-1] == "head"
    # residual blocks are ONE unit (the block input stays live for the
    # shortcut); non-residual blocks split at their expand/dw edges
    segs = dict(mg.units())
    res_units = [n for n in names if n.startswith("block") and "." not in n]
    assert any(len([m for m in segs[n] if m.op in ("conv", "dwconv")]) == 3
               for n in res_units)          # expand+dw+project in one unit
    assert any("." in n for n in names)     # and split non-residual blocks
    vg = V_CFG.graph()
    vnames = [name for name, _ in vg.units()]
    # fused repvgg is a pure chain: one conv per unit, a cut on every edge
    assert len(vnames) == len(repvgg.block_specs(V_CFG)) + 1
    assert all(len([m for m in seg if m.op == "conv"]) == 1
               for name, seg in vg.units()[:-1])


def test_graph_edge_bytes_match_legacy_resnet_accounting():
    """The graph's cut-edge byte counts equal the legacy ResNet-specific
    ``edge_bytes_after_block`` (incl. the stem-maxpool special case), so
    graph-planned stages keep the exact link accounting the Fig 7 tests
    pin down."""
    g = R_CFG.graph()
    blocks = resnet.conv_blocks_for(R_CFG)
    legacy = [partition.edge_bytes_after_block(blocks, j)
              for j in range(len(blocks))]
    assert g.edge_bytes() == legacy
    # and plans built from graph blocks + edge bytes carry those bytes
    plans = partition.plan_stages(g.blocks(), 3, g.edge_bytes())
    for p in plans[:-1]:
        assert p.link_bytes == legacy[p.block_ids[-1]]
    assert plans[-1].link_bytes == 0


# ---------------------------------------------------------------------------
# ResNet: graph path bit-identical to the pre-graph hand-rolled units
# ---------------------------------------------------------------------------

def _legacy_unit_chain(params, cfg):
    """The pre-graph compiled forward, reproduced verbatim from the old
    hand-rolled ``resnet._stem_unit``/``_block_unit``/``_head_unit`` —
    the bit-identity pin for the graph refactor."""
    def row_scale(s):
        return jnp.asarray(s).reshape((-1,) + (1,) * 3)

    def stem(p, x):
        x_q, s = cl.act_quant(x, per_row=True)
        h = resnet._conv_q(p, x_q, s, relu=True)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                  (1, 2, 2, 1), "SAME")
        return cl.act_quant(h, per_row=True)

    def block(p, carry):
        h_q, s_h = carry
        sc = (resnet._conv_q(p["sc"], h_q, s_h, relu=False) if "sc" in p
              else h_q.astype(jnp.float32) * row_scale(s_h))
        a_q, s_a = resnet._conv_q(p["a"], h_q, s_h, quant_out=True)
        b_q, s_b = resnet._conv_q(p["b"], a_q, s_a, quant_out=True)
        h = resnet._conv_q(p["c"], b_q, s_b, shortcut=sc, relu=True)
        return cl.act_quant(h, per_row=True)

    def head(p, carry):
        h_q, s_h = carry
        pooled = jnp.mean(h_q.astype(jnp.float32) * row_scale(s_h),
                          axis=(1, 2))
        return cl.apply_linear(p["w"], pooled, per_row=True)

    fns = [lambda c, p=params["stem"]: stem(p, c)]
    for i in range(4):
        for blk in params[cfg.stage(i)[0]]:
            fns.append(lambda c, p=blk: block(p, c))
    fns.append(lambda c, p=params["head"]: head(p, c))
    return fns


@pytest.mark.parametrize("mode", ["int8", "sparse_cfmm"])
def test_resnet_graph_bit_identical_to_legacy_units(monkeypatch, mode):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = nn.unbox(cl.compile_params(R_CFG.init(jax.random.PRNGKey(0)),
                                        mode=mode, sparsity=0.5))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    units = compile_graph(R_CFG.graph(), params)
    legacy = _legacy_unit_chain(params, R_CFG)
    assert len(units) == len(legacy)
    carry_g, carry_l = x, x
    for u, lf in zip(units, legacy):
        carry_g = u.fn(u.params, carry_g)
        carry_l = lf(carry_l)
        for got, want in zip(jax.tree.leaves(carry_g),
                             jax.tree.leaves(carry_l)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert carry_g.shape == (2, R_CFG.num_classes)


# ---------------------------------------------------------------------------
# RepVGG: compile-time branch fusion
# ---------------------------------------------------------------------------

def test_repvgg_embed_equals_true_1x1_at_stride_1():
    """At stride 1 (SAME pad 1 each side for k=3) the center-embedded 1x1
    weight IS the 1x1 conv — the algebra behind the fold."""
    key = jax.random.PRNGKey(0)
    c_in, c_out = 8, 16
    w1 = jax.random.normal(key, (c_in, c_out))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 7, 9, c_in))
    p1 = {"w": w1, "scale": jnp.ones(c_out), "bias": jnp.zeros(c_out)}
    p3 = {"w": repvgg.embed_1x1(w1, c_in), "scale": jnp.ones(c_out),
          "bias": jnp.zeros(c_out)}
    got = resnet._conv_apply(p3, x, 3, 1, relu=False)
    want = resnet._conv_apply(p1, x, 1, 1, relu=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_repvgg_fusion_matches_unfused_reference():
    """fuse_params folds 3x3 + 1x1 + identity (and their per-channel
    scales/biases) into one 3x3 conv per block: the fused dense forward
    matches the three-branch reference to fp tolerance, end to end over
    stride-2, identity, and non-identity blocks."""
    params = V_CFG.init(jax.random.PRNGKey(0))
    fused = V_CFG.fuse(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3)) * 0.5
    want = V_CFG.apply(nn.unbox(params), x)
    got = V_CFG.apply(nn.unbox(fused), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # the A0 chain really exercises all three block flavours
    specs = repvgg.block_specs(V_CFG)
    assert any(s[4] for s in specs) and any(not s[4] for s in specs)
    assert any(s[3] == 2 for s in specs)


def test_repvgg_fused_compiled_bit_identical_across_lowerings(monkeypatch):
    params = cl.compile_params(V_CFG.fuse(V_CFG.init(jax.random.PRNGKey(0))),
                               mode="int8")
    params = nn.unbox(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    outs = {}
    for lowering in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", lowering)
        outs[lowering] = np.asarray(V_CFG.apply(params, x))
    np.testing.assert_array_equal(outs["jnp"], outs["interpret"])


# ---------------------------------------------------------------------------
# Depthwise kernel vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strip_h", [None, 1, 2])
@pytest.mark.parametrize("k,stride", [(3, 1), (3, 2)])
def test_depthwise_bit_identical_across_strip_tilings(monkeypatch, k,
                                                      stride, strip_h):
    """The Pallas tap-MAC depthwise kernel (interpret) agrees bit-exactly
    with the jnp oracle for every strip tiling, quantized output and
    per-row scales included."""
    key = jax.random.PRNGKey(k + 10 * stride)
    C, H, W = 16, 7, 9
    x_q = jax.random.randint(key, (2, H, W, C), -127, 128, jnp.int8)
    w = jax.random.randint(jax.random.PRNGKey(1), (k * k, C), -63, 64,
                           jnp.int8)
    x_s = jnp.asarray([0.013, 0.021])           # per-row domains
    w_s = 0.02 * jnp.ones((1, C))
    outs = {}
    for lowering in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", lowering)
        outs[lowering] = ops.conv2d_dw(
            x_q, w, k, stride, x_scale=x_s, w_scale=w_s,
            gamma=jnp.ones(C), beta=jnp.zeros(C), relu=True,
            quant_out=True, strip_h=strip_h)
    for got, want in zip(jax.tree.leaves(outs["interpret"]),
                         jax.tree.leaves(outs["jnp"])):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Frontend input geometry (regression: was hardcoded 224x224x3-style
# cfg.in_hw with channel 3 fixed)
# ---------------------------------------------------------------------------

def test_frontend_validates_against_graph_geometry(monkeypatch):
    from repro.serving.frontend import FrontendRequest, ResNetFrontend
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = nn.unbox(cl.compile_params(M_CFG.init(jax.random.PRNGKey(0)),
                                        mode="int8"))
    fe = ResNetFrontend(M_CFG, params, mode="int8", n_replicas=1,
                        n_stages=1, microbatch=2)
    ok = FrontendRequest(rid=1, images=np.zeros((1, 16, 16, 3), np.float32))
    fe.run([ok])
    assert ok.done and ok.logits.shape == (1, 4)
    with pytest.raises(ValueError, match=r"\(n, 16, 16, 3\)"):
        fe.submit(FrontendRequest(
            rid=2, images=np.zeros((1, 224, 224, 3), np.float32)))


# ---------------------------------------------------------------------------
# ResNetConfig.expansion satellite
# ---------------------------------------------------------------------------

def test_resnet_expansion_config_field():
    cfg = resnet.ResNetConfig(width_mult=0.125, expansion=2)
    for i in range(4):
        name, _, mid, out, _ = cfg.stage(i)
        assert out == max(8, 2 * mid) or out == 8
    # default matches Table I exactly
    cfg4 = resnet.ResNetConfig()
    assert [cfg4.stage(i)[3] for i in range(4)] == [256, 512, 1024, 2048]
    with pytest.raises(ValueError, match="expansion"):
        resnet.ResNetConfig(expansion=0)
    resnet.table1()                                  # expansion=4 fine
    with pytest.raises(ValueError, match="expansion\\*mid"):
        resnet.table1(expansion=2)


def test_resnet_nondefault_expansion_serves(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    cfg = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8,
                              expansion=2)
    params = cl.compile_params(cfg.init(jax.random.PRNGKey(0)), mode="int8")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    out = cfg.apply(nn.unbox(params), x)
    assert out.shape == (2, 4) and bool(jnp.isfinite(out).all())

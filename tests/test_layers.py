import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.models import attention as attn
from repro.models.layers import apply_rope, layernorm, layernorm_init, \
    rmsnorm, rmsnorm_init


def test_rmsnorm_unit_scale():
    p = {"scale": jnp.ones((16,))}
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 7.0
    y = rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_layernorm_zero_mean():
    p = layernorm_init(jax.random.PRNGKey(0), 16)
    p = jax.tree.map(lambda q: q.value, p,
                     is_leaf=lambda x: hasattr(x, "axes"))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) + 3.0
    y = layernorm(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-3)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))
    def dot_at(p):
        qr = apply_rope(q, jnp.array([[p]]))
        kr = apply_rope(k, jnp.array([[p + 3]]))
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(0) - dot_at(17)) < 1e-3


def test_mrope_sections_match_standard_when_positions_equal():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 2, 32))
    pos = jnp.arange(6)[None].repeat(2, 0)
    std = apply_rope(x, pos)
    mp = jnp.broadcast_to(pos[None], (3, 2, 6))
    mr = apply_rope(x, mp, mrope_sections=(8, 4, 4))
    np.testing.assert_allclose(np.asarray(std), np.asarray(mr), rtol=1e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_flash_attention_matches_naive(causal, window):
    B, H, T, D = 2, 3, 48, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, T, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, D))
    out = attn.flash_attention(q, k, v, causal=causal, window=window,
                               q_chunk=16, kv_chunk=16)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_rectangular_kv():
    """Queries at the end of a longer kv sequence (prefill continuation)."""
    B, H, Tq, Tk, D = 1, 2, 8, 32, 16
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, H, Tq, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, Tk, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, Tk, D))
    out = attn.flash_attention(q, k, v, causal=True, q_chunk=4, kv_chunk=8)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_gqa_equals_mha_when_groups_one():
    B, T, H, D = 2, 12, 4, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, D))
    o1 = attn.gqa_attention(q, k, v)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_decode_attention_matches_full():
    B, S, KVH, G, D = 2, 16, 2, 3, 8
    H = KVH * G
    key = jax.random.PRNGKey(0)
    ck = jax.random.normal(key, (B, S, KVH, D))
    cv = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KVH, D))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, H, D))
    length = 10
    out = attn.decode_attention(q, ck, cv, length)
    full = attn.gqa_attention(q, ck[:, :length], cv[:, :length], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=2e-3, atol=2e-3)

import numpy as np

from repro.data.pipeline import DataConfig, SyntheticDataset


def test_deterministic_per_step():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=3)
    a = SyntheticDataset(cfg).batch(7)
    b = SyntheticDataset(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticDataset(cfg).batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_partitions_batch():
    cfg = DataConfig(vocab=128, seq_len=8, global_batch=8, seed=0)
    h0 = SyntheticDataset(cfg, 0, 2).batch(3)
    h1 = SyntheticDataset(cfg, 1, 2).batch(3)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_markov_structure_learnable():
    cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=1,
                     branching=4)
    ds = SyntheticDataset(cfg)
    b = ds.batch(0)["tokens"]
    # every transition is one of the 4 successors of the previous token
    for row in b:
        for t in range(1, len(row)):
            assert row[t] in ds.successors[row[t - 1]]
    assert abs(ds.entropy_floor - np.log(4)) < 1e-9

"""Prefill + decode must reproduce the full forward pass (per arch)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.configs.base import ARCH_IDS, get_config
from repro.models import lm, moe

# generous MoE capacity so token-drop nondeterminism between different
# sequence lengths doesn't mask cache bugs (see test_moe for drop tests)
_orig_moe = moe.moe_forward


@pytest.fixture(autouse=True)
def _loose_capacity(monkeypatch):
    monkeypatch.setattr(moe, "moe_forward",
                        functools.partial(_orig_moe, capacity_factor=16.0))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = nn.unbox(lm.init(key, cfg))
    B, T = 2, 16
    kw = {}
    if cfg.encoder_decoder:
        kw["frames"] = jax.random.normal(key, (B, 24, cfg.d_model),
                                         jnp.float32).astype(jnp.bfloat16)
        cache = nn.unbox(lm.cache_init(cfg, B, 32, S_enc=24))
    else:
        cache = nn.unbox(lm.cache_init(cfg, B, 32))
    if cfg.pos == "mrope":
        kw["positions"] = jnp.broadcast_to(jnp.arange(T)[None, None],
                                           (3, B, T))
    toks = jax.random.randint(key, (B, T), 1, cfg.vocab)
    full, _ = lm.forward_train(params, {**kw, "tokens": toks,
                                        "labels": toks}, cfg)
    pre_kw = dict(kw)
    if cfg.pos == "mrope":
        pre_kw["positions"] = kw["positions"][:, :, :T - 4]
    lg, cache = lm.forward_prefill(params, {**pre_kw,
                                            "tokens": toks[:, :T - 4]},
                                   cfg, cache)
    outs = [lg]
    for t in range(T - 4, T):
        step_b = {"token": toks[:, t:t + 1]}
        if cfg.pos == "mrope":
            step_b["positions"] = kw["positions"][:, :, t:t + 1]
        lg, cache = lm.forward_decode(params, step_b, cfg, cache)
        outs.append(lg)
    dec = jnp.concatenate(outs[:-1], axis=1).astype(jnp.float32)
    ref = full[:, T - 5:T - 1].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(dec - ref)) / jnp.maximum(
        jnp.max(jnp.abs(ref)), 1e-6))
    agree = float(jnp.mean((jnp.argmax(dec, -1)
                            == jnp.argmax(ref, -1)).astype(jnp.float32)))
    if cfg.moe is None:
        assert rel < 0.06, f"{arch}: decode relerr {rel:.4f}"
        # random-init logits tie easily; a disagreement only counts if the
        # reference top-1 beat top-2 by a real margin (not a bf16 tie-flip)
        top2 = jnp.sort(ref, axis=-1)[..., -2:]
        margin = (top2[..., 1] - top2[..., 0]) / jnp.maximum(
            jnp.max(jnp.abs(ref)), 1e-6)
        disagree = jnp.argmax(dec, -1) != jnp.argmax(ref, -1)
        real_disagree = jnp.logical_and(disagree, margin > 0.05)
        assert not bool(jnp.any(real_disagree)), (
            f"{arch}: non-tie greedy disagreement (agree={agree:.2f})")
    else:
        # MoE routing is a discrete boundary: bf16 cache rounding can flip
        # a near-tied top-k choice, so elementwise logits are checked by
        # median, plus greedy-token agreement (taxonomy: discrete_boundary)
        med = float(jnp.median(jnp.abs(dec - ref)) /
                    jnp.maximum(jnp.max(jnp.abs(ref)), 1e-6))
        assert med < 0.02, f"{arch}: decode median relerr {med:.4f}"
        assert agree >= 0.85, f"{arch}: greedy agreement {agree:.2f}"

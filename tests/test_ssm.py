import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.configs.base import get_config
from repro.models import ssm


@pytest.fixture(scope="module")
def mamba_cfg():
    return get_config("jamba_v01_52b").reduced()


@pytest.fixture(scope="module")
def rwkv_cfg():
    return get_config("rwkv6_7b").reduced()


def test_mamba_chunked_matches_sequential(mamba_cfg):
    cfg = mamba_cfg
    key = jax.random.PRNGKey(0)
    p = nn.unbox(ssm.mamba_init(key, cfg))
    x = jax.random.normal(key, (2, 20, cfg.d_model), jnp.float32)
    y_chunk, _ = ssm.mamba_forward(p, x, cfg, chunk=8)
    y_seq = ssm.mamba_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_mamba_state_carry_decode(mamba_cfg):
    """prefill(T) then decode(1) == forward(T+1) at the last position."""
    cfg = mamba_cfg
    key = jax.random.PRNGKey(1)
    p = nn.unbox(ssm.mamba_init(key, cfg))
    x = jax.random.normal(key, (1, 9, cfg.d_model), jnp.float32)
    full, _ = ssm.mamba_forward(p, x, cfg, chunk=4)
    _, st = ssm.mamba_forward(p, x[:, :8], cfg, chunk=4)
    step, _ = ssm.mamba_forward(p, x[:, 8:9], cfg, state=st)
    np.testing.assert_allclose(np.asarray(step[:, 0], np.float32),
                               np.asarray(full[:, 8], np.float32),
                               rtol=3e-2, atol=3e-2)


def test_rwkv_chunked_matches_stepwise(rwkv_cfg):
    cfg = rwkv_cfg
    key = jax.random.PRNGKey(0)
    p = nn.unbox(ssm.rwkv6_init(key, cfg))
    B, T = 1, 12
    x = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    y_chunk, _ = ssm.rwkv6_forward(p, x, cfg, chunk=4)
    # stepwise decode reference
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd
    state = {"shift": jnp.zeros((B, 1, cfg.d_model), jnp.float32),
             "wkv": jnp.zeros((B, H, hd, hd), jnp.float32)}
    outs = []
    for t in range(T):
        y, state = ssm.rwkv6_forward(p, x[:, t:t + 1], cfg, state=state)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=4e-2, atol=4e-2)


def test_rwkv_decay_in_unit_interval(rwkv_cfg):
    cfg = rwkv_cfg
    p = nn.unbox(ssm.rwkv6_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    y, st = ssm.rwkv6_forward(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    assert bool(jnp.all(jnp.isfinite(st["wkv"])))

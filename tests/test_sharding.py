import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shd


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_divisibility_guard():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for(("embed", "heads_q"), (960, 960), shd.TRAIN_RULES,
                        mesh)
    assert spec == P("data", "model")   # 960 % 16 == 0: both shard
    # truly indivisible out dim is dropped (replicated)
    spec = shd.spec_for(("embed", "heads_q"), (960, 15 * 66),
                        shd.TRAIN_RULES, mesh)
    assert spec == P("data")
    # dp_only folds both mesh axes onto the batch/in dims
    spec = shd.spec_for(("embed", "heads_q"), (1024, 512),
                        shd.DP_ONLY_TRAIN_RULES, mesh)
    assert spec == P(("data", "model"))


def test_spec_uniqueness_guard():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for(("experts_stack", "embed", "ffn_in"),
                        (64, 2048, 1024), shd.TRAIN_RULES, mesh)
    # experts take 'model'; ffn_in must NOT reuse it
    assert spec == P("model", "data")


def test_param_shardings_tree():
    from repro import nn
    import jax.numpy as jnp
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    tree = {"w": nn.Param(jnp.zeros((64, 32)), ("embed", "ffn_in"), "linear")}
    sh = shd.param_shardings(tree, mesh)
    assert sh["w"].spec == P()   # axes of size 1 -> everything replicated


DRYRUN_MINI = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import functools, jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh
from repro import nn
from repro.configs.base import get_config
from repro.distributed import sharding as shd
from repro.models import lm
from repro.training import optimizer
from repro.training.train_step import make_train_step

cfg = get_config("{arch}").reduced()
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
params = jax.eval_shape(functools.partial(lm.init, cfg=cfg),
                        jax.random.PRNGKey(0))
p_shard = shd.param_shardings(params, mesh)
opt_shapes = jax.eval_shape(optimizer.init, nn.unbox(params))
o_shard = optimizer.OptState(
    jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    p_shard, p_shard)
batch = {{"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32)}}
b_shard = shd.batch_shardings(batch, mesh)
with mesh:
    lowered = jax.jit(make_train_step(cfg),
                      in_shardings=(p_shard, o_shard, b_shard)).lower(
        nn.unbox(params), opt_shapes, batch)
    compiled = lowered.compile()
print("MINI_DRYRUN_OK", compiled.cost_analysis() is not None)
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm_360m", "olmoe_1b_7b"])
def test_mini_dryrun_2x2_mesh(arch, tmp_path):
    """Real lower+compile on a 2x2 host-device mesh (subprocess so the
    device-count override doesn't leak into this process)."""
    script = tmp_path / "mini.py"
    script.write_text(DRYRUN_MINI.format(arch=arch))
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, timeout=420,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert "MINI_DRYRUN_OK" in r.stdout, r.stderr[-2000:]

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.launch.train import build_cfg
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def test_engine_matches_manual_greedy_decode():
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = list(np.random.RandomState(0).randint(1, cfg.vocab, 10))
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=2,
                           max_seq=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    engine.run([req])

    # manual reference: prefill + greedy decode with batch 1
    pv = nn.unbox(params)
    cache = nn.unbox(lm.cache_init(cfg, 1, 32))
    toks = jnp.asarray(np.asarray(prompt)[None], jnp.int32)
    logits, cache = lm.forward_prefill(pv, {"tokens": toks}, cfg, cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(5):
        logits, cache = lm.forward_decode(
            pv, {"token": jnp.asarray([[out[-1]]], jnp.int32)}, cfg, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    assert req.tokens_out == out


def test_engine_continuous_batching_refills_slots():
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=2,
                           max_seq=32)
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, prompt=list(rng.randint(1, cfg.vocab, 8)),
                    max_new_tokens=4) for i in range(5)]
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.tokens_out) == 4 for r in reqs)


def test_compiled_modes_storage_shrinks():
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)

    def nbytes(engine):
        return sum(np.asarray(v).nbytes
                   for v in jax.tree.leaves(engine.params))

    dense = nbytes(ServingEngine(cfg, params, mode="dense", batch_slots=1,
                                 max_seq=16))
    int8 = nbytes(ServingEngine(cfg, params, mode="int8", batch_slots=1,
                                max_seq=16))
    sparse = nbytes(ServingEngine(cfg, params, mode="sparse_cfmm",
                                  batch_slots=1, max_seq=16))
    assert sparse < int8 < dense

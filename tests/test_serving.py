import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.launch.train import build_cfg
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def test_engine_matches_manual_greedy_decode():
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = list(np.random.RandomState(0).randint(1, cfg.vocab, 10))
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=2,
                           max_seq=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    engine.run([req])

    # manual reference: prefill + greedy decode with batch 1
    pv = nn.unbox(params)
    cache = nn.unbox(lm.cache_init(cfg, 1, 32))
    toks = jnp.asarray(np.asarray(prompt)[None], jnp.int32)
    logits, cache = lm.forward_prefill(pv, {"tokens": toks}, cfg, cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(5):
        logits, cache = lm.forward_decode(
            pv, {"token": jnp.asarray([[out[-1]]], jnp.int32)}, cfg, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    assert req.tokens_out == out


def test_engine_continuous_batching_refills_slots():
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=2,
                           max_seq=32)
    rng = np.random.RandomState(1)
    reqs = [Request(rid=i, prompt=list(rng.randint(1, cfg.vocab, 8)),
                    max_new_tokens=4) for i in range(5)]
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.tokens_out) == 4 for r in reqs)


def test_prefill_cache_bucketed_and_bounded():
    """The engine compiles one prefill program per power-of-two length
    bucket (not per distinct prompt length) and LRU-bounds the cache —
    many distinct lengths share a handful of programs, and the bucketed
    (end-padded) prefill still reproduces the exact unpadded decode."""
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=1,
                           max_seq=64)
    rng = np.random.RandomState(2)
    lengths = list(range(3, 19))                  # 16 distinct lengths
    reqs = [Request(rid=i, prompt=list(rng.randint(1, cfg.vocab, L)),
                    max_new_tokens=3) for i, L in enumerate(lengths)]
    engine.run(reqs)
    assert all(r.done for r in reqs)
    # buckets {8, 16, 32} only — 3 programs for 16 lengths
    assert set(engine._prefill_cache) <= {8, 16, 32}
    assert len(engine._prefill_cache) <= 3

    # exactness: bucketed (end-padded) prefill == manual unpadded
    # reference, on a fresh engine per prompt (the shared decode cache's
    # scalar length counter max-merges across sequential requests — a
    # pre-existing engine property independent of bucketing)
    pv = nn.unbox(params)
    for r in (reqs[0], reqs[-1]):
        fresh = ServingEngine(cfg, params, mode="dense", batch_slots=1,
                              max_seq=64)
        rf = Request(rid=0, prompt=list(r.prompt), max_new_tokens=3)
        fresh.run([rf])
        cache = nn.unbox(lm.cache_init(cfg, 1, 64))
        toks = jnp.asarray(np.asarray(r.prompt)[None], jnp.int32)
        logits, cache = lm.forward_prefill(pv, {"tokens": toks}, cfg, cache)
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(2):
            logits, cache = lm.forward_decode(
                pv, {"token": jnp.asarray([[out[-1]]], jnp.int32)}, cfg,
                cache)
            out.append(int(jnp.argmax(logits[0, -1])))
        assert rf.tokens_out == out


def test_recurrent_arch_prefills_exact_length():
    """Bucketing is gated on attention-only stacks: pad tokens advance
    mamba/rwkv recurrent scan states that no length rewind can undo, so
    a recurrent engine prefills at exact prompt length (still LRU-
    bounded) and keeps matching the manual unpadded reference."""
    cfg = build_cfg("rwkv6_7b", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=1,
                           max_seq=32)
    assert not engine._bucket_prefill
    prompt = list(np.random.RandomState(4).randint(1, cfg.vocab, 5))
    req = Request(rid=0, prompt=prompt, max_new_tokens=3)
    engine.run([req])
    assert 5 in engine._prefill_cache        # exact length, not bucket 8

    pv = nn.unbox(params)
    cache = nn.unbox(lm.cache_init(cfg, 1, 32))
    toks = jnp.asarray(np.asarray(prompt)[None], jnp.int32)
    logits, cache = lm.forward_prefill(pv, {"tokens": toks}, cfg, cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(2):
        logits, cache = lm.forward_decode(
            pv, {"token": jnp.asarray([[out[-1]]], jnp.int32)}, cfg, cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    assert req.tokens_out == out


def test_prefill_cache_lru_eviction():
    """The LRU backstop evicts the oldest bucket once PREFILL_CACHE_MAX
    distinct buckets have been compiled."""
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=1,
                           max_seq=256)
    engine.PREFILL_CACHE_MAX = 2
    rng = np.random.RandomState(3)
    for L in (5, 12, 30):                         # buckets 8, 16, 32
        engine.run([Request(rid=L, prompt=list(rng.randint(1, cfg.vocab, L)),
                            max_new_tokens=1)])
    assert list(engine._prefill_cache) == [16, 32]   # 8 evicted, LRU order


def test_max_new_tokens_one_gets_exactly_one_token():
    """Request lifecycle: prefill already yields the first token, so a
    max_new_tokens=1 request must complete right after prefill — the old
    step() unconditionally ran a decode on the freshly-admitted slot and
    returned 2 tokens."""
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=2,
                           max_seq=32)
    rng = np.random.RandomState(5)
    reqs = [Request(rid=i, prompt=list(rng.randint(1, cfg.vocab, 6)),
                    max_new_tokens=1) for i in range(3)]
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert [len(r.tokens_out) for r in reqs] == [1, 1, 1]
    # the freed slot admits the next queued request before any decode:
    # 3 requests through 2 slots with zero decode steps required
    assert all(s is None for s in engine.active)


def test_prefill_eos_completes_without_decode():
    """An EOS produced BY PREFILL must finish the request — the old path
    never checked it and decoded past the EOS."""
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = list(np.random.RandomState(6).randint(1, cfg.vocab, 7))
    probe = Request(rid=0, prompt=list(prompt), max_new_tokens=4)
    ServingEngine(cfg, params, mode="dense", batch_slots=1,
                  max_seq=32).run([probe])
    first = probe.tokens_out[0]                # what prefill will emit
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=1,
                           max_seq=32)
    req = Request(rid=1, prompt=list(prompt), max_new_tokens=4,
                  eos_id=first)
    engine.run([req])
    assert req.done and req.tokens_out == [first]


def test_overlong_prompt_rejected_at_submit():
    """A prompt longer than max_seq can't fit the (1, bucket) prefill
    buffer (_bucket_len caps the bucket at max_seq) — submit() rejects it
    with a clear error instead of a numpy shape error mid-prefill."""
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode="dense", batch_slots=1,
                           max_seq=16)
    long_prompt = list(np.random.RandomState(7).randint(1, cfg.vocab, 17))
    with pytest.raises(ValueError, match="max_seq"):
        engine.submit(Request(rid=0, prompt=long_prompt))
    assert not engine.queue                    # nothing half-admitted
    # a decode budget that would overrun the cache is rejected too:
    # decode token i lands at position L + i - 2, which
    # dynamic_update_slice would silently CLAMP past max_seq
    with pytest.raises(ValueError, match="decode budget"):
        engine.submit(Request(rid=2, prompt=long_prompt[:16],
                              max_new_tokens=4))
    # boundaries that exactly fit still serve: L == max_seq with one
    # (prefill-produced) token, and L + budget - 1 == max_seq
    ok = Request(rid=1, prompt=long_prompt[:16], max_new_tokens=1)
    engine.run([ok])
    assert ok.done and len(ok.tokens_out) == 1
    ok2 = Request(rid=3, prompt=long_prompt[:13], max_new_tokens=4)
    engine.run([ok2])
    assert ok2.done and len(ok2.tokens_out) == 4


def test_compiled_modes_storage_shrinks():
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)

    def nbytes(engine):
        return sum(np.asarray(v).nbytes
                   for v in jax.tree.leaves(engine.params))

    dense = nbytes(ServingEngine(cfg, params, mode="dense", batch_slots=1,
                                 max_seq=16))
    int8 = nbytes(ServingEngine(cfg, params, mode="int8", batch_slots=1,
                                max_seq=16))
    sparse = nbytes(ServingEngine(cfg, params, mode="sparse_cfmm",
                                  batch_slots=1, max_seq=16))
    assert sparse < int8 < dense

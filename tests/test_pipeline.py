"""Pipeline-parallel serving subsystem (the executable Fig 7).

Conformance: `PipelineEngine` output must be *bit-identical* to the
single-device compiled ResNet path (the jitted whole-model forward at the
same microbatch granularity) for n_stages in {1, 2, 4}, in every serve
mode, through both the jnp and REPRO_PALLAS=interpret lowerings — the
`tests/test_serve_modes.py` matrix extended over stage counts.  Plus: the
per-stage persistent-weights property (disjoint param subtrees), measured
vs analytic inter-stage link bytes, the greedy packer's oversized-layer
guard, stage-plan algebra, and a forced-4-device subprocess harness.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.core import partition
from repro.core.fpga_model import GX280, ConvLayerSpec
from repro.models import resnet
from repro.serving.pipeline import (PipelineEngine, PipelineRequest,
                                    reference_logits)

CFG = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
MODES = [m for m in cl.SERVE_MODES if m != "dense"]
STAGE_COUNTS = (1, 2, 4)

_params_cache = {}


def _compiled(mode):
    """Compiled tiny-ResNet params, cached per mode (compile once)."""
    if mode not in _params_cache:
        params = resnet.init(jax.random.PRNGKey(0), CFG)
        _params_cache[mode] = nn.unbox(
            cl.compile_params(params, mode=mode, sparsity=0.5))
    return _params_cache[mode]


def _images(n, seed=1):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                        (n, CFG.in_hw, CFG.in_hw, 3)))


_ref_cache = {}


def _reference(mode, lowering, n, microbatch):
    key = (mode, lowering, n, microbatch)
    if key not in _ref_cache:
        _ref_cache[key] = np.asarray(reference_logits(
            _compiled(mode), CFG, jnp.asarray(_images(n)), microbatch))
    return _ref_cache[key]


# ---------------------------------------------------------------------------
# Conformance matrix: serve mode x stage count x lowering
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_stages", STAGE_COUNTS)
@pytest.mark.parametrize("mode", MODES)
def test_pipeline_bit_identical_jnp(monkeypatch, mode, n_stages):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    eng = PipelineEngine(CFG, _compiled(mode), mode=mode,
                         n_stages=n_stages, microbatch=2)
    out = eng.run_batch(_images(4))
    np.testing.assert_array_equal(np.asarray(out),
                                  _reference(mode, "jnp", 4, 2))
    assert len(eng.plan) == n_stages
    assert eng.stats()["ticks"] == 2 + n_stages - 1    # M + S - 1


@pytest.mark.slow
@pytest.mark.parametrize("n_stages", STAGE_COUNTS)
@pytest.mark.parametrize("mode", MODES)
def test_pipeline_bit_identical_interpret(monkeypatch, mode, n_stages):
    """The same matrix through the Pallas kernels in interpret mode
    (single image/microbatch — interpret is slow)."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    eng = PipelineEngine(CFG, _compiled(mode), mode=mode,
                         n_stages=n_stages, microbatch=1)
    out = eng.run_batch(_images(1))
    np.testing.assert_array_equal(np.asarray(out),
                                  _reference(mode, "interpret", 1, 1))


def test_single_stage_degenerates_to_apply(monkeypatch):
    """n_stages=1 with one whole-batch microbatch IS the single-device
    compiled path: same values as jit(resnet.apply), bit for bit."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = _compiled("int8")
    x = _images(4)
    eng = PipelineEngine(CFG, params, mode="int8", n_stages=1, microbatch=4)
    out = eng.run_batch(x)
    want = jax.jit(lambda p, a: resnet.apply(p, a, CFG))(params,
                                                         jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_requests_independent_and_engine_persistent(monkeypatch):
    """Requests are independent — per-row quantization domains mean one
    request's logits cannot depend on its queue neighbours even though
    microbatches DO pack rows across request boundaries (r1's odd size
    makes r1 row 2 and r2 row 0 share a microbatch here) — and the
    engine serves wave after wave with its weights staying resident."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = _compiled("int8")
    eng = PipelineEngine(CFG, params, mode="int8", n_stages=2, microbatch=2)
    x = _images(8)
    r1 = PipelineRequest(rid=1, images=x[:3])       # odd size: partial mb
    r2 = PipelineRequest(rid=2, images=x[3:8])
    eng.run([r1, r2])
    assert r1.done and r2.done
    # the packing really was cross-request: 8 rows in ceil(8/2)=4 full
    # microbatches, not 2+3 per-request ones
    assert eng.stats()["mb_injected"] == 4
    assert eng.stats()["microbatch_occupancy"] == 1.0
    # each request equals ITS OWN per-microbatch reference
    np.testing.assert_array_equal(
        r1.logits, np.asarray(reference_logits(params, CFG,
                                               jnp.asarray(x[:3]), 2)))
    np.testing.assert_array_equal(
        r2.logits, np.asarray(reference_logits(params, CFG,
                                               jnp.asarray(x[3:8]), 2)))
    # second wave on the same engine: same inputs, same bits
    r3 = PipelineRequest(rid=3, images=x[:3])
    eng.run([r3])
    np.testing.assert_array_equal(r3.logits, r1.logits)


def test_zero_row_request_completes(monkeypatch):
    """A request with no images completes immediately with empty logits
    instead of hanging undone in the queue."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    eng = PipelineEngine(CFG, _compiled("int8"), mode="int8", n_stages=2,
                         microbatch=2)
    req = PipelineRequest(rid=9, images=_images(4)[:0])
    eng.run([req])
    assert req.done and req.logits.shape == (0, CFG.num_classes)
    assert eng.run_batch(_images(4)[:0]).shape == (0, CFG.num_classes)


def test_reference_logits_zero_rows():
    """Regression: ``reference_logits`` on a zero-row batch used to
    ``jnp.concatenate`` an empty microbatch list and raise — it must
    return empty ``(0, num_classes)`` logits like the engine does."""
    out = reference_logits(_compiled("int8"), CFG,
                           jnp.asarray(_images(4)[:0]), 2)
    assert out.shape == (0, CFG.num_classes)
    assert out.dtype == jnp.float32


def test_cross_request_packing_bit_identical(monkeypatch):
    """The tentpole invariant: rows from MANY single-image requests pack
    into shared microbatches (continuous batching), and every request's
    logits are bit-identical to its own single-request reference — for
    every serve mode, with ``pack_requests`` on and off."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    x = _images(6)
    for mode in MODES:
        params = _compiled(mode)
        refs = [np.asarray(reference_logits(params, CFG,
                                            jnp.asarray(x[i:i + 1]), 2))
                for i in range(6)]
        for pack in (True, False):
            eng = PipelineEngine(CFG, params, mode=mode, n_stages=2,
                                 microbatch=4, pack_requests=pack)
            reqs = [PipelineRequest(rid=i, images=x[i:i + 1])
                    for i in range(6)]
            eng.run(reqs)
            for i, r in enumerate(reqs):
                assert r.done, (mode, pack, i)
                np.testing.assert_array_equal(r.logits, refs[i])
            st = eng.stats()
            if pack:        # 6 single rows -> ceil(6/4)=2 microbatches
                assert st["mb_injected"] == 2 and st["rows_injected"] == 6
            else:           # baseline: one microbatch per request
                assert st["mb_injected"] == 6
                assert st["microbatch_occupancy"] == 0.25


def test_pending_rows_incremental_matches_scan(monkeypatch):
    """``pending_rows`` is O(1) incremental state; it must equal the
    linear-scan oracle ``_scan_pending_rows`` at every step of a mixed
    whole-request / row-span workload, and reach 0 when idle."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    eng = PipelineEngine(CFG, _compiled("int8"), mode="int8", n_stages=2,
                         microbatch=2)
    x = _images(8)
    r1 = PipelineRequest(rid=1, images=x[:5])
    r2 = PipelineRequest(rid=2, images=x[5:])
    eng.submit(r1)
    assert eng.pending_rows == eng._scan_pending_rows() == 5
    # row-span path: r2 arrives as two spans (the front door's move)
    r2.logits = None
    eng.submit_rows(r2, 0, 2)
    eng.submit_rows(r2, 2, 3)
    assert eng.pending_rows == eng._scan_pending_rows() == 8
    while eng.step():
        assert eng.pending_rows == eng._scan_pending_rows()
    assert eng.pending_rows == 0 and r1.done and r2.done
    np.testing.assert_array_equal(
        r2.logits, np.asarray(reference_logits(_compiled("int8"), CFG,
                                               jnp.asarray(x[5:]), 2)))


def test_explicit_stage_map_and_partition_plan(monkeypatch):
    """Both alternate planning paths — an explicit block map and a
    Fig 7 ``PartitionResult`` — produce conformant engines."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = _compiled("int8")
    want = _reference("int8", "jnp", 4, 2)
    eng = PipelineEngine(CFG, params, mode="int8", microbatch=2,
                         stage_blocks=[(0, 1, 2), tuple(range(3, 17))])
    np.testing.assert_array_equal(np.asarray(eng.run_batch(_images(4))),
                                  want)
    blocks = resnet.conv_blocks_for(CFG)
    result = partition.partition(blocks, 1000.0)
    eng2 = PipelineEngine(CFG, params, mode="int8", microbatch=2,
                          n_stages=2, plan=result)
    assert len(eng2.plan) == 2
    np.testing.assert_array_equal(np.asarray(eng2.run_batch(_images(4))),
                                  want)


# ---------------------------------------------------------------------------
# Persistent per-stage weights: disjoint param subtrees (spy)
# ---------------------------------------------------------------------------

def _leaf_bytes(tree):
    return sum(l.nbytes for l in jax.tree.leaves(tree))


@pytest.mark.parametrize("mode", ["int8", "sparse_cfmm"])
def test_stage_params_disjoint(monkeypatch, mode):
    """Each stage holds exactly its own units' constant weights: unit
    names partition the model, per-stage resident bytes equal the sum of
    that stage's unit subtrees, and nothing is replicated."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = _compiled(mode)
    eng = PipelineEngine(CFG, params, mode=mode, n_stages=4, microbatch=2)
    units = resnet.compiled_units(params, CFG)
    unit_bytes = {u.name: _leaf_bytes(u.params) for u in units}
    seen = []
    for stage in eng.pipe.stages:
        seen.extend(stage.unit_names)
        assert stage.weight_bytes() == sum(
            unit_bytes[n] for n in stage.unit_names)
    assert sorted(seen) == sorted(unit_bytes)          # disjoint + complete
    assert sum(st.weight_bytes() for st in eng.pipe.stages) == \
        _leaf_bytes([u.params for u in units])
    # conv leaves specifically: every compiled conv leaf lives on exactly
    # one stage
    def conv_leaf_count(tree):
        flat, _ = jax.tree.flatten(
            tree, is_leaf=lambda t: isinstance(t, dict) and "geom" in t)
        return sum(1 for leaf in flat
                   if isinstance(leaf, dict) and "geom" in leaf)
    total = conv_leaf_count(params)
    assert total == sum(conv_leaf_count(st.params)
                        for st in eng.pipe.stages)


# ---------------------------------------------------------------------------
# Link bytes: measured == executable plan == Fig 7 analytic
# ---------------------------------------------------------------------------

def test_edge_bytes_measured_vs_analytic(monkeypatch):
    """The int8 payload the executed pipeline actually moves on each edge
    equals StagePlan.link_bytes x microbatch, and those link byte counts
    agree with PartitionResult.link_gbps' analytic accounting at the
    matching chip boundaries."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    mb = 2
    blocks = resnet.conv_blocks_for(CFG)
    result = partition.partition(blocks, 1000.0)
    plans = result.stage_plans(blocks)                 # chip-aligned
    eng = PipelineEngine(CFG, _compiled("int8"), mode="int8",
                         plan=plans, microbatch=mb)
    eng.run_batch(_images(4))
    st = eng.stats()
    for e, measured in enumerate(st["edge_bytes"]):
        assert measured["int8_bytes"] == plans[e].link_bytes * mb, (
            e, measured, plans[e])
        assert measured["meta_bytes"] == 4 * mb    # one f32 scale PER ROW
    # the tiny config's chip-aligned plan can degenerate to one stage
    # (no edges) — force a 2-stage split so the edge assertions above
    # aren't vacuous
    eng2 = PipelineEngine(CFG, _compiled("int8"), mode="int8",
                          n_stages=2, microbatch=mb)
    eng2.run_batch(_images(4))
    edges2 = eng2.stats()["edge_bytes"]
    assert edges2, "2-stage engine must have a measured edge"
    for e, measured in enumerate(edges2):
        assert measured["int8_bytes"] == eng2.plan[e].link_bytes * mb
        # per-row quantization domains (DESIGN.md §9): the edge carries
        # mb scales, one per image — 4*mb meta bytes, still dwarfed by
        # the int8 payload
        assert measured["meta_bytes"] == 4 * mb
    # analytic cross-check: a stage boundary that coincides with a chip
    # boundary carries the chip link's bytes (the stem edge is the
    # documented exception: the executed link is post-maxpool, /4)
    chip_of = {}
    for chip in result.chips:
        for p in chip.layers:
            chip_of.setdefault(p["layer"], chip.index)
    for plan in plans[:-1]:
        last_block = blocks[plan.block_ids[-1]]
        chip = chip_of[last_block[0].name]
        chip_last = result.chips[chip].layers[-1]["spec"]
        if chip_last.name == last_block[-1].name:      # aligned boundary
            want = chip_last.out_bytes
            if plan.block_ids[-1] == 0:
                want //= 4                             # stride-2 maxpool
            assert plan.link_bytes == want
            if plan.block_ids[-1] != 0:
                assert abs(plan.link_gbps(result.achieved_im_s)
                           - result.link_gbps[chip]) < 1e-9


def test_edge_bytes_after_block():
    blocks = resnet.resnet50_conv_blocks()
    # stem: conv1 makes a 112x112x64 map, the executable edge is pooled
    assert partition.edge_bytes_after_block(blocks, 0) == 56 * 56 * 64
    # a conv2_x block edge: 56x56x256 int8
    assert partition.edge_bytes_after_block(blocks, 1) == 56 * 56 * 256
    # last conv5_x block: 7x7x2048
    assert partition.edge_bytes_after_block(blocks, 16) == 7 * 7 * 2048


# ---------------------------------------------------------------------------
# Stage-plan algebra + the greedy packer guard
# ---------------------------------------------------------------------------

def test_split_stages_properties():
    costs = [3, 1, 4, 1, 5, 9, 2, 6]
    for n in (1, 2, 3, 5, 8, 20):
        groups = partition.split_stages(costs, n)
        assert [i for g in groups for i in g] == list(range(len(costs)))
        assert len(groups) == min(n, len(costs))
        assert all(g for g in groups)


def test_plan_stages_balance_and_links():
    blocks = resnet.resnet50_conv_blocks()
    total_macs = sum(l.macs for blk in blocks for l in blk)
    for n in (1, 2, 4):
        plans = partition.plan_stages(blocks, n)
        assert len(plans) == n
        assert sum(p.macs for p in plans) == total_macs
        assert plans[-1].link_bytes == 0
        for p in plans[:-1]:
            assert p.link_bytes == partition.edge_bytes_after_block(
                blocks, p.block_ids[-1])
        ids = [i for p in plans for i in p.block_ids]
        assert ids == list(range(len(blocks)))


def test_stage_plans_from_fig7_partition():
    blocks = resnet.resnet50_conv_blocks()
    result = partition.partition(blocks, 10_000.0)
    plans = result.stage_plans(blocks)
    ids = [i for p in plans for i in p.block_ids]
    assert ids == list(range(len(blocks)))             # block-aligned
    assert all(p.alms > 0 for p in plans)
    coalesced = result.stage_plans(blocks, 4)
    assert len(coalesced) == 4
    assert sum(p.macs for p in coalesced) == sum(p.macs for p in plans)


def test_partition_oversized_layer_guard():
    """A layer whose single kernel instance exceeds the usable fabric at
    the model's maximum fold must raise, not emit >100%-utilized chips
    (the old packer reported 200% utilization as success)."""
    huge = ConvLayerSpec("huge", 4096, 4096, 3, 56)
    with pytest.raises(partition.PartitionError, match="huge"):
        partition.partition([[huge]], 53_061.0)
    # the guard does not fire for anything in the paper's own network
    result = partition.partition(resnet.resnet50_conv_blocks(), 53_061.0)
    cap = GX280.usable_alms(0.76)
    assert all(c.alms_used <= cap + 1e-6 for c in result.chips)


# ---------------------------------------------------------------------------
# Multi-device harness (forced 4-device CPU fan-out, subprocess)
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import jax, numpy as np, jax.numpy as jnp
from repro import nn
from repro.core.compiled_linear import compile_params
from repro.models import resnet
from repro.serving.pipeline import PipelineEngine, reference_logits

assert len(jax.devices()) == 4, jax.devices()
cfg = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
params = nn.unbox(compile_params(resnet.init(jax.random.PRNGKey(0), cfg),
                                 mode="int8"))
x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3)))
eng = PipelineEngine(cfg, params, mode="int8", n_stages=4, microbatch=1)
devs = {str(s.device) for s in eng.pipe.stages}
assert len(devs) == 4, devs                       # one stage per device
for s in eng.pipe.stages:                         # weights live on-stage
    for leaf in jax.tree.leaves(s.params):
        assert list(leaf.devices())[0] == s.device, (s.index, leaf.devices())
out = eng.run_batch(x)
ref = reference_logits(params, cfg, jnp.asarray(x), 1)
np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
print("MULTIDEV_OK", sorted(devs))
"""


def test_pipeline_on_four_forced_devices():
    """Real multi-device placement: stage params committed to 4 distinct
    CPU devices, int8 edges crossing devices, output bit-identical to the
    single-device reference.  Subprocess because device count is fixed at
    backend init (the in-process suite must keep seeing one device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4")
    env["REPRO_PALLAS"] = "jnp"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                          capture_output=True, text=True, timeout=300,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTIDEV_OK" in proc.stdout

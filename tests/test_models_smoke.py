"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro import nn
from repro.configs.base import ARCH_IDS, get_config
from repro.models import lm
from repro.training import optimizer
from repro.training.train_step import make_train_step


def _batch(cfg, key, B=2, T=32):
    if cfg.encoder_decoder:
        return {"frames": jnp.zeros((B, T, cfg.d_model), jnp.bfloat16),
                "tokens": jax.random.randint(key, (B, 16), 1, cfg.vocab),
                "labels": jax.random.randint(key, (B, 16), 1, cfg.vocab)}
    b = {"tokens": jax.random.randint(key, (B, T), 1, cfg.vocab),
         "labels": jax.random.randint(key, (B, T), 1, cfg.vocab)}
    if cfg.pos == "mrope":
        pos = jnp.broadcast_to(jnp.arange(T)[None, None], (3, B, T))
        b["positions"] = pos
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = nn.unbox(lm.init(key, cfg))
    batch = _batch(cfg, key)
    logits, aux = lm.forward_train(params, batch, cfg)
    T_out = 16 if cfg.encoder_decoder else 32
    assert logits.shape == (2, T_out, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = nn.unbox(lm.init(key, cfg))
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(cfg, optimizer.OptConfig(lr=1e-3,
                                                            warmup_steps=2,
                                                            total_steps=10)))
    new_p, new_o, metrics = step(params, opt_state, _batch(cfg, key))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_o.step) == 1
    # parameters actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_p))
    assert max(moved) > 0


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    spec = {
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "jamba_v01_52b": (32, 4096, 32, 8, 14336, 65536),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 10944, 102400),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
    }
    for arch, (L, d, H, KV, dff, V) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab) == (L, d, H, KV, dff, V), arch


def test_moe_configs():
    c = get_config("deepseek_v2_lite_16b")
    assert c.moe.n_experts == 64 and c.moe.top_k == 6 and c.moe.n_shared == 2
    assert c.moe.d_ff_expert == 1408 and c.mla.kv_lora == 512
    c = get_config("olmoe_1b_7b")
    assert c.moe.n_experts == 64 and c.moe.top_k == 8
    c = get_config("jamba_v01_52b")
    assert c.moe.n_experts == 16 and c.moe.top_k == 2
    assert c.layer_pattern.count("mamba") == 7  # 1:7 attn:mamba

"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiled_linear as cl
from repro.core.quantize import quantize_int7
from repro.kernels import ops, ref


@pytest.mark.parametrize("M,K,N", [(8, 512, 128), (128, 1024, 256),
                                   (100, 960, 384), (1, 512, 128),
                                   (17, 2048, 128)])
def test_cfmm_matmul_kernel_exact(M, K, N):
    key = jax.random.PRNGKey(M * K + N)
    x = jax.random.randint(key, (M, K), -127, 128, jnp.int8)
    qt = quantize_int7(jax.random.normal(key, (K, N)))
    y = ops.cfmm_matmul(x, qt.values)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(ref.int8_matmul_ref(x, qt.values)))


def test_tile_pad_prime_dims_not_degenerate():
    """Regression for the _largest_tile pathology: a prime axis above the
    tile cap used to degrade to tile size 1 (one grid cell per column);
    _tile_pad pads to the next cap multiple instead."""
    from repro.kernels.ops import _tile_pad
    assert _tile_pad(131, 128) == (128, 256)           # prime N
    assert _tile_pad(1031, 512) == (512, 1536)         # prime K
    assert _tile_pad(134, 128) == (128, 256)           # 2*67: tile 67 is
    assert _tile_pad(128, 128) == (128, 128)           # no sublane multiple
    assert _tile_pad(96, 128) == (96, 96)              # fits: single tile
    assert _tile_pad(256, 128) == (128, 256)
    assert _tile_pad(192, 128) == (96, 192)            # clean divisor kept
    # 8*prime: the largest divisor is a sliver tile of 8 — pad instead
    assert _tile_pad(8 * 131, 128) == (128, 1152)
    assert _tile_pad(8 * 521, 512) == (512, 4608)


def test_cfmm_matmul_prime_dims_exact():
    """Prime K and N run the padded-tile path and stay exact (the zero
    pad rows/cols vanish under int8 matmul)."""
    key = jax.random.PRNGKey(4)
    M, K, N = 4, 1031, 131
    x = jax.random.randint(key, (M, K), -127, 128, jnp.int8)
    qt = quantize_int7(jax.random.normal(key, (K, N)))
    y = ops.cfmm_matmul(x, qt.values)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(ref.int8_matmul_ref(x, qt.values)))
    ys = ops.cfmm_matmul(x, qt.values, qt.scale.reshape(1, N))
    np.testing.assert_allclose(
        np.asarray(ys),
        np.asarray(ref.int8_matmul_ref(x, qt.values), np.float32)
        * np.asarray(qt.scale.reshape(1, N)), rtol=1e-6)


def test_sparse_matvec_prime_n_exact():
    """Prime N through the bitmap kernel: padded zero bitmap columns
    expand to zero codes, sliced off after the launch."""
    key = jax.random.PRNGKey(6)
    K, N, keep = 512, 131, 104
    qt = cl.balanced_prune_codes(jax.random.normal(key, (K, N)), keep)
    bitmap, values = cl.bitmap_pack(qt.values, keep)
    x = jax.random.randint(key, (4, K), -127, 128, jnp.int8)
    y = ops.sparse_cfmm_matmul(x, bitmap, values)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(ref.sparse_matvec_ref(x, bitmap, values)))


def test_conv_prime_n_out_exact():
    """Prime c_out > 128 through the conv kernel pads channels to the
    lane tile and slices back — bit-identical to the jnp oracle."""
    k, C, n_out = 3, 8, 131
    key = jax.random.PRNGKey(8)
    x = jax.random.randint(key, (1, 8, 8, C), -127, 128, jnp.int8)
    qt = quantize_int7(jax.random.normal(key, (C * k * k, n_out)) * 0.1)
    y = ops.conv2d(x, qt.values, k, 1, x_scale=1.0,
                   w_scale=jnp.ones((n_out,)), relu=False)
    acc = ref.conv2d_int8_ref(x, qt.values, k, 1)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(acc).astype(np.float32))


@pytest.mark.parametrize("M,K,N", [(8, 512, 128), (4, 1024, 256)])
def test_cfmm_matmul_fused_scale(M, K, N):
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (M, K), -127, 128, jnp.int8)
    qt = quantize_int7(jax.random.normal(key, (K, N)))
    scale = qt.scale.reshape(1, N)
    y = ops.cfmm_matmul(x, qt.values, scale)
    expect = np.asarray(ref.int8_matmul_ref(x, qt.values), np.float32) * \
        np.asarray(scale)
    np.testing.assert_allclose(np.asarray(y), expect, rtol=1e-6)


@pytest.mark.parametrize("M,K,N,s", [(8, 1024, 128, 0.8), (4, 2048, 256, 0.9),
                                     (8, 960, 128, 0.8), (1, 512, 128, 0.5)])
def test_sparse_matvec_kernel_exact(M, K, N, s):
    key = jax.random.PRNGKey(K + N)
    w = jax.random.normal(key, (K, N))
    keep = max(8, int(K * (1 - s)) // 8 * 8)
    qt = cl.balanced_prune_codes(w, keep)
    bitmap, values = cl.bitmap_pack(qt.values, keep)
    x = jax.random.randint(key, (M, K), -127, 128, jnp.int8)
    y = ops.sparse_cfmm_matmul(x, bitmap, values)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(ref.sparse_matvec_ref(x, bitmap, values)))


@pytest.mark.parametrize("M,K,N", [(64, 512, 256), (128, 256, 384),
                                   (8, 256, 128)])
def test_block_sparse_kernel(M, K, N):
    key = jax.random.PRNGKey(7)
    w = np.array(jax.random.normal(key, (K, N)))
    w[:128, :128] = 0.0             # whole-block zeros get dropped
    if K >= 512:
        w[256:384, :] = 0.0
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, K))
    y = ops.block_sparse_matmul(x, jnp.asarray(w), (128, 128))
    ref_y = x @ jnp.asarray(w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y),
                               rtol=2e-5, atol=2e-4)


def test_block_sparse_skips_zero_blocks():
    from repro.kernels.block_sparse import plan_blocks
    mask = np.zeros((4, 3), bool)
    mask[0, 0] = mask[2, 0] = mask[1, 2] = True
    meta = plan_blocks(mask)
    assert meta.shape == (4, 3)           # only 3 active of 12 blocks
    assert list(meta[1]) == [0, 0, 2]     # column-major n order
    assert list(meta[2]) == [1, 0, 1]     # first-of-column flags
    assert list(meta[3]) == [0, 1, 1]     # last-of-column flags


def test_bitmap_pack_storage_budget():
    w = jax.random.normal(jax.random.PRNGKey(0), (4096, 256))
    keep = 4096 // 5 // 8 * 8
    qt = cl.balanced_prune_codes(w, keep)
    bitmap, values = cl.bitmap_pack(qt.values, keep)
    bits_per_param = (bitmap.size + values.size) * 8 / (4096 * 256)
    assert bits_per_param < 2.7           # ~(1-s)*8 + 1 bits


@pytest.mark.parametrize("causal,window,G,Dv", [
    (True, None, 1, 32), (True, None, 4, 32), (False, None, 2, 32),
    (True, 64, 2, 32), (True, None, 2, 16)])
def test_flash_attention_kernel_vs_oracle(causal, window, G, Dv):
    import jax.numpy as jnp
    from repro.kernels.ops import flash_attention
    B, KVH, Tq, Tk, D = 1, 2, 128, 256, 32
    key = jax.random.PRNGKey(G * 7 + Dv)
    q = jax.random.normal(key, (B, KVH, G, Tq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, KVH, Tk, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, KVH, Tk, Dv))
    out = flash_attention(q, k, v, causal=causal, window=window)
    # oracle: naive softmax per (kv-head, group)
    qf = q.reshape(B, KVH * G, Tq, D)
    kf = jnp.repeat(k, G, axis=1).reshape(B, KVH * G, Tk, D)
    vf = jnp.repeat(v, G, axis=1).reshape(B, KVH * G, Tk, Dv)
    want = ref.flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out.reshape(B, KVH * G, Tq, Dv), np.float32),
        np.asarray(want, np.float32), rtol=2e-3, atol=2e-3)

"""Per-row quantization domains (DESIGN.md §9) — the invariant behind
continuous cross-request batching.

Property level: quantizing rows together with ``per_row=True`` is
bit-identical to quantizing each row alone, at every layer of the stack
(core.quantize, compiled_linear.act_quant/apply_linear, the fused conv
Collector through both lowerings).  Model level, two tiers of contract:

* the jnp oracle lowering is fully packing-invariant — ANY chunking of a
  batch into microbatches produces bit-identical logits, every serve
  mode;
* the Pallas kernel lowerings are neighbour- and position-invariant at a
  FIXED microbatch shape (a row's bits never depend on who shares its
  microbatch or where it sits), but executables for different batch
  shapes may differ by data-dependent FMA-contraction ulps — the same
  caveat serving.pipeline.reference_logits documents for eager-vs-jit.

That pair is exactly what lets serving pack rows from different requests
into one fixed-size microbatch (serving/pipeline.py) and split one
request across replicas (serving/frontend.py) without changing anyone's
answer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro import nn
from repro.core import compiled_linear as cl
from repro.core import quantize as q
from repro.kernels import ops
from repro.models import resnet

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

CFG = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
MODES = [m for m in cl.SERVE_MODES if m != "dense"]


# ---------------------------------------------------------------------------
# core.quantize / compiled_linear: row independence as a property
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(2, 24))
def test_quantize_act_int8_per_row_is_rowwise(seed, n, d):
    """``per_row=True`` == quantizing each row alone: codes AND scales.
    Mixing a huge-magnitude row with a tiny one must not change the tiny
    row's codes (the precise failure of per-tensor domains)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    x = x.at[0].mul(100.0)                 # domain-poisoning neighbour
    qt = q.quantize_act_int8(x, per_row=True)
    assert qt.scale.shape == (n,) + (1,) * (x.ndim - 1)
    for i in range(n):
        alone = q.quantize_act_int8(x[i:i + 1], per_row=True)
        np.testing.assert_array_equal(np.asarray(qt.values[i:i + 1]),
                                      np.asarray(alone.values))
        np.testing.assert_array_equal(np.asarray(qt.scale[i:i + 1]),
                                      np.asarray(alone.scale))
    # legacy per-tensor domain unchanged: scalar scale, shared by all rows
    legacy = q.quantize_act_int8(x)
    assert legacy.scale.ndim == 0


@given(st.integers(0, 2**31 - 1))
def test_act_quant_per_row_matches_slices(seed):
    """compiled_linear.act_quant(per_row=True) returns (N,) scales and is
    bit-identical to quantizing each image alone — NHWC rank included."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 4, 4, 8)) * \
        jnp.asarray([0.01, 1.0, 50.0]).reshape(3, 1, 1, 1)
    x_q, s = cl.act_quant(x, per_row=True)
    assert s.shape == (3,) and s.dtype == jnp.float32
    for i in range(3):
        qi, si = cl.act_quant(x[i:i + 1], per_row=True)
        np.testing.assert_array_equal(np.asarray(x_q[i:i + 1]),
                                      np.asarray(qi))
        np.testing.assert_array_equal(np.asarray(s[i:i + 1]),
                                      np.asarray(si))
    # per-tensor path untouched: scalar scale
    _, s_t = cl.act_quant(x)
    assert s_t.ndim == 0


def test_apply_linear_per_row_rows_independent():
    """The classifier head's per-row path: each row of the int8 matmul
    output equals the row computed alone, so the head cannot couple
    microbatch neighbours (the bug that POOLED per-tensor act_quant over
    the batch used to introduce)."""
    key = jax.random.PRNGKey(0)
    w = cl._compile_leaf_2d(jax.random.normal(key, (16, 4)), "int8", 0.0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (5, 16)) * \
        jnp.asarray([100.0, 1.0, 0.02, 3.0, 7.0]).reshape(5, 1)
    y = cl.apply_linear(w, x, per_row=True)
    for i in range(5):
        yi = cl.apply_linear(w, x[i:i + 1], per_row=True)
        np.testing.assert_array_equal(np.asarray(y[i:i + 1]),
                                      np.asarray(yi))


# ---------------------------------------------------------------------------
# Fused conv Collector: per-row domains through both lowerings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lowering", ["jnp", "interpret"])
def test_conv2d_per_row_matches_row_slices(monkeypatch, lowering):
    """conv2d with an (N,) x_scale and quant_out: every output row (codes
    and its emitted y_scale) is bit-identical to running that image
    alone — the kernel's per-image eff_scale row and per-row amax
    reduction compose correctly in both lowerings."""
    monkeypatch.setenv("REPRO_PALLAS", lowering)
    k, stride, N = 3, 1, 3
    key = jax.random.PRNGKey(2)
    x = jax.random.randint(key, (N, 8, 8, 8), -127, 128, jnp.int8)
    qt = q.quantize_int7(
        jax.random.normal(jax.random.fold_in(key, 1), (8 * k * k, 16)) * 0.1)
    s_x = jnp.asarray([0.01, 0.5, 2.0], jnp.float32)   # one domain per image
    y_q, s_y = ops.conv2d(x, qt.values, k, stride, x_scale=s_x,
                          w_scale=qt.scale.reshape(-1), quant_out=True)
    assert s_y.shape == (N,)
    for i in range(N):
        yi, si = ops.conv2d(x[i:i + 1], qt.values, k, stride,
                            x_scale=s_x[i:i + 1],
                            w_scale=qt.scale.reshape(-1), quant_out=True)
        np.testing.assert_array_equal(np.asarray(y_q[i:i + 1]),
                                      np.asarray(yi))
        np.testing.assert_array_equal(np.asarray(s_y[i:i + 1]),
                                      np.asarray(si))
    # scalar x_scale still means per-tensor: scalar y_scale (legacy)
    _, s_leg = ops.conv2d(x, qt.values, k, stride, x_scale=0.05,
                          w_scale=qt.scale.reshape(-1), quant_out=True)
    assert s_leg.ndim == 0


# ---------------------------------------------------------------------------
# Model level: packing invariance = the continuous-batching licence
# ---------------------------------------------------------------------------

_params_cache = {}


def _compiled(mode):
    if mode not in _params_cache:
        params = resnet.init(jax.random.PRNGKey(0), CFG)
        _params_cache[mode] = nn.unbox(
            cl.compile_params(params, mode=mode, sparsity=0.5))
    return _params_cache[mode]


def _images(n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (n, CFG.in_hw, CFG.in_hw, 3))


@pytest.mark.parametrize("mode", MODES)
def test_forward_packing_invariant_jnp(monkeypatch, mode):
    """Bit-identical logits for ANY chunking of the batch — including
    chunkings that pack what were different requests' rows together."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    params = _compiled(mode)
    x = _images(6)
    fn = jax.jit(lambda p, a: resnet.apply(p, a, CFG))
    full = np.asarray(fn(params, x))
    for bounds in ([0, 1, 6], [0, 2, 4, 6], [0, 3, 6], [0, 5, 6]):
        got = np.concatenate([np.asarray(fn(params, x[a:b]))
                              for a, b in zip(bounds, bounds[1:])])
        np.testing.assert_array_equal(got, full, err_msg=str((mode, bounds)))


@pytest.mark.parametrize("mode", ["int8", "sparse_cfmm"])
def test_forward_neighbour_invariant_interpret(monkeypatch, mode):
    """The serving-relevant invariant through the Pallas kernels
    (interpret mode, batch-2 cells — interpret is slow): at a fixed
    microbatch shape, a row's logits are bit-identical no matter WHO
    shares its microbatch or WHERE in it the row sits — which is what
    continuous cross-request batching swaps around.  (Bit-identity
    across different batch SHAPES is the jnp oracle's contract above;
    compiled lowerings may differ across shapes by FMA-contraction
    ulps, which is why the engine packs fixed-size microbatches.)"""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    params = _compiled(mode)
    x = _images(4)
    fn = jax.jit(lambda p, a: resnet.apply(p, a, CFG))
    ab = np.asarray(fn(params, x[jnp.asarray([0, 1])]))
    ac = np.asarray(fn(params, x[jnp.asarray([0, 2])]))
    da = np.asarray(fn(params, x[jnp.asarray([3, 0])]))
    np.testing.assert_array_equal(ab[0], ac[0])    # neighbour swapped
    np.testing.assert_array_equal(ab[0], da[1])    # position swapped
    np.testing.assert_array_equal(ac[1:], np.asarray(
        fn(params, x[jnp.asarray([2, 3])]))[:1])   # both at once

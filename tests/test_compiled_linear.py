import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro import nn
from repro.core import compiled_linear as cl

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _w(key, K, N):
    return nn.Param(jax.random.normal(key, (K, N)) * 0.05,
                    ("embed", "ffn_in"), "linear")


def test_modes_agree_with_dense():
    key = jax.random.PRNGKey(0)
    p = {"w": _w(key, 256, 64)}
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 256)) * 0.5
    ref = cl.apply_linear(nn.unbox(p)["w"], x)
    for mode in ("int8", "cfmm", "bitserial"):
        packed = nn.unbox(cl.compile_params(p, mode=mode))
        y = cl.apply_linear(packed["w"], x)
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 0.03, (mode, rel)
    # int8 and cfmm must be bit-identical (same storage + math)
    y8 = cl.apply_linear(nn.unbox(cl.compile_params(p, mode="int8"))["w"], x)
    yc = cl.apply_linear(nn.unbox(cl.compile_params(p, mode="cfmm"))["w"], x)
    np.testing.assert_array_equal(np.asarray(y8), np.asarray(yc))


def test_sparse_mode_matches_pruned_dense():
    key = jax.random.PRNGKey(1)
    p = {"w": _w(key, 512, 64)}
    packed = nn.unbox(cl.compile_params(p, mode="sparse_cfmm", sparsity=0.8))
    assert set(packed["w"]) == {"bitmap", "values", "scale"}
    # reconstruct dense codes and compare against the packed forward
    codes = cl.bitmap_unpack(packed["w"]["bitmap"], packed["w"]["values"])
    x = jax.random.normal(key, (4, 512))
    y = cl.apply_linear(packed["w"], x)
    y_ref = cl.apply_linear({"values": codes,
                             "scale": packed["w"]["scale"]}, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=1e-3,
                               atol=1e-4)
    sparsity = float(np.mean(np.asarray(codes) == 0))
    assert 0.75 <= sparsity <= 0.85


def test_compile_params_only_touches_linear_kind():
    key = jax.random.PRNGKey(2)
    p = {"w": _w(key, 64, 32),
         "norm": nn.Param(jnp.ones((32,)), ("embed",)),
         "emb": nn.Param(jax.random.normal(key, (100, 32)),
                         ("vocab", "embed"))}
    packed = cl.compile_params(p, mode="int8")
    assert isinstance(packed["norm"], nn.Param)       # untouched
    assert isinstance(packed["emb"], nn.Param)        # untouched (generic)
    assert isinstance(packed["w"], dict)              # packed


def test_stacked_expert_weights_pack_per_expert():
    key = jax.random.PRNGKey(3)
    w = nn.Param(jax.random.normal(key, (4, 64, 32)) * 0.05,
                 ("experts_stack", "embed", "ffn_in"), "linear")
    packed = cl.compile_params({"w": w}, mode="int8")
    assert packed["w"]["values"].value.shape == (4, 64, 32)
    assert packed["w"]["scale"].value.shape == (4, 1, 32)
    # per-expert scales differ (independent channels)
    s = np.asarray(packed["w"]["scale"].value)
    assert np.std(s) > 0


@given(st.integers(0, 10_000), st.sampled_from([64, 128, 256]),
       st.sampled_from([16, 48]))
def test_qdq_error_bounded(seed, K, N):
    key = jax.random.PRNGKey(seed)
    p = {"w": _w(key, K, N)}
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, K))
    ref = cl.apply_linear(nn.unbox(p)["w"], x)
    y = cl.apply_linear(nn.unbox(cl.compile_params(p, mode="int8"))["w"], x)
    rel = float(jnp.linalg.norm(y - ref) / jnp.maximum(jnp.linalg.norm(ref),
                                                       1e-9))
    assert rel < 0.05


def test_qat_forward_matches_int7_grid():
    from repro.core.quantize import fake_quant_int7, quantize_int7
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    fq = fake_quant_int7(w)
    qt = quantize_int7(w)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(qt.dequantize()),
                               rtol=1e-5, atol=1e-7)

"""Serve-mode conformance matrix: every mode in SERVE_MODES through a
full residual block (a/b/c convs + projection shortcut + quantization-
domain pass), asserting the jnp-oracle and REPRO_PALLAS=interpret
lowerings agree — bit-exactly on the int paths (the int8 activations
between convs), to fp tolerance on the f32 epilogue output —
parameterized over the Table I corner geometries (test_conv.GEOMS)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.models import resnet
from test_conv import GEOMS

IN_CH, MID, OUT = 8, 8, 16
H, W = 7, 9                            # odd-spatial corner


def _block_params(k, stride, seed=0):
    """One bottleneck residual block; (k, stride) rides the main b conv,
    the projection shortcut strides to match."""
    keys = iter(jax.random.split(jax.random.PRNGKey(seed + 7 * k), 8))
    return {
        "a": resnet._conv_init(next(keys), IN_CH, MID, 1),
        "b": resnet._conv_init(next(keys), MID, MID, k, stride=stride),
        "c": resnet._conv_init(next(keys), MID, OUT, 1),
        "sc": resnet._conv_init(next(keys), IN_CH, OUT, 1, stride=stride),
    }


def _block_forward(params, x, k, stride):
    """The resnet residual-block dataflow, dense or compiled depending on
    the leaf form — mirrors models/resnet.apply's two paths."""
    if not isinstance(params["a"]["w"], dict):     # dense training path
        sc = resnet._conv_apply(params["sc"], x, 1, stride, relu=False)
        y = resnet._conv_apply(params["a"], x, 1)
        y = resnet._conv_apply(params["b"], y, k, stride)
        h = resnet._conv_apply(params["c"], y, 1, relu=True, shortcut=sc)
        return h, None, None
    # one quant per block, PER-ROW domains like models/resnet.apply
    # (DESIGN.md §9) — scales are (N,), every row its own domain
    x_q, s = cl.act_quant(x, per_row=True)
    sc = resnet._conv_q(params["sc"], x_q, s, relu=False)
    a_q, s_a = resnet._conv_q(params["a"], x_q, s, quant_out=True)
    b_q, s_b = resnet._conv_q(params["b"], a_q, s_a, quant_out=True)
    h = resnet._conv_q(params["c"], b_q, s_b, shortcut=sc, relu=True)
    return h, a_q, b_q


@pytest.mark.slow
@pytest.mark.parametrize("k,stride", GEOMS)
@pytest.mark.parametrize("mode", cl.SERVE_MODES)
def test_block_lowerings_agree(monkeypatch, mode, k, stride):
    params = _block_params(k, stride)
    served = nn.unbox(params) if mode == "dense" else \
        nn.unbox(cl.compile_params(params, mode=mode, sparsity=0.5))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, H, W, IN_CH))
    outs = {}
    for lowering in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", lowering)
        outs[lowering] = _block_forward(served, x, k, stride)
    h_j, a_j, b_j = outs["jnp"]
    h_i, a_i, b_i = outs["interpret"]
    if mode != "dense":
        # int paths bit-exact: the quantization-domain int8 activations
        # handed between convs are identical across lowerings
        np.testing.assert_array_equal(np.asarray(a_j), np.asarray(a_i))
        np.testing.assert_array_equal(np.asarray(b_j), np.asarray(b_i))
    np.testing.assert_allclose(np.asarray(h_j), np.asarray(h_i),
                               rtol=1e-5, atol=1e-5)
    assert h_j.shape == (2, -(-H // stride), -(-W // stride), OUT)


@pytest.mark.parametrize("mode", [m for m in cl.SERVE_MODES if m != "dense"])
def test_block_modes_within_quant_tolerance_of_dense(mode):
    """Sanity anchor for the matrix: every compiled mode's block output
    stays within quantization tolerance of the dense training path (on
    the pruned subspace for sparse_cfmm, as in test_conv)."""
    k, stride = 3, 1
    params = _block_params(k, stride)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, H, W, IN_CH)) * 0.5
    if mode == "sparse_cfmm":
        # compare on the pruned model: dense reference uses the same
        # pruned weights the packed leaves carry
        served = nn.unbox(cl.compile_params(params, mode=mode,
                                            sparsity=0.5))
        pruned = jax.tree.map(lambda p: p, params,
                              is_leaf=lambda t: isinstance(t, nn.Param))
        for name in ("a", "b", "c", "sc"):
            codes = cl.packed_codes(served[name]["w"])
            wd = codes.astype(jnp.float32) * served[name]["w"]["scale"]
            pruned[name]["w"] = nn.Param(wd, params[name]["w"].axes,
                                         params[name]["w"].kind)
        want, _, _ = _block_forward(nn.unbox(pruned), x, k, stride)
    else:
        served = nn.unbox(cl.compile_params(params, mode=mode))
        want, _, _ = _block_forward(nn.unbox(params), x, k, stride)
    got, _, _ = _block_forward(served, x, k, stride)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.08, (mode, rel)


# ---------------------------------------------------------------------------
# Model-zoo matrix: every zoo member x serve mode x lowering (DESIGN.md §12)
# ---------------------------------------------------------------------------
from repro.models import mobilenet_v2 as mb      # noqa: E402
from repro.models import repvgg                  # noqa: E402

ZOO = ("resnet50", "mobilenet_v2", "repvgg_a0")


def _zoo_cfg_params(model):
    """Tiny-width smoke config + servable (boxed) params per zoo member.
    RepVGG serves its compile-time-fused single-branch form."""
    if model == "resnet50":
        cfg = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
        return cfg, cfg.init(jax.random.PRNGKey(0))
    if model == "mobilenet_v2":
        cfg = mb.MobileNetV2Config(width_mult=0.125, num_classes=4,
                                   in_hw=16)
        return cfg, cfg.init(jax.random.PRNGKey(0))
    cfg = repvgg.RepVGGConfig(width_mult=0.125, num_classes=4, in_hw=16)
    return cfg, cfg.fuse(cfg.init(jax.random.PRNGKey(0)))


@pytest.mark.slow
@pytest.mark.parametrize("mode", ("int8", "cfmm", "sparse_cfmm"))
@pytest.mark.parametrize("model", ZOO)
def test_zoo_lowerings_agree(monkeypatch, model, mode):
    """jnp oracle vs Pallas interpret, whole model end to end: every
    activation edge is (int8, scale), so the final logits must agree
    bit-exactly across lowerings for every zoo member x serve mode."""
    cfg, raw = _zoo_cfg_params(model)
    params = nn.unbox(cl.compile_params(raw, mode=mode, sparsity=0.5))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.in_hw, cfg.in_hw, 3))
    outs = {}
    for lowering in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", lowering)
        outs[lowering] = np.asarray(cfg.apply(params, x))
    np.testing.assert_array_equal(outs["jnp"], outs["interpret"])
    assert outs["jnp"].shape == (2, cfg.num_classes)
    assert np.isfinite(outs["jnp"]).all()


@pytest.mark.parametrize("mode", ("int8", "cfmm"))
@pytest.mark.parametrize("model", ZOO)
def test_zoo_compiled_tracks_reference(monkeypatch, model, mode):
    """Whole-model quantization sanity per zoo member: the compiled
    int8-edge forward stays within the block-level quant tolerance of its
    own f32 reference (dense resnet/mobilenet; fused dense repvgg)."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    cfg, raw = _zoo_cfg_params(model)
    compiled = nn.unbox(cl.compile_params(raw, mode=mode))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.in_hw, cfg.in_hw, 3)) * 0.5
    got = np.asarray(cfg.apply(compiled, x))
    want = np.asarray(cfg.apply(nn.unbox(raw), x))
    rel = (np.linalg.norm(got - want)
           / max(np.linalg.norm(want), 1e-9))
    assert rel < 0.08, f"{model}/{mode}: rel={rel:.4f}"

"""Drop-in stand-in for the slice of hypothesis the test-suite uses.

This container does not ship ``hypothesis``; rather than skipping the
property tests entirely, each ``@given`` test falls back to a fixed-seed
loop over drawn examples — deterministic, dependency-free, and still a
real (if smaller) sweep of the input space.  Test modules import it as

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

(pytest puts tests/ on sys.path because it is not a package).
"""
from __future__ import annotations

import random
import sys
import zlib

N_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: rng.choice(opts))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def given(*strats: _Strategy):
    def deco(fn):
        # NB: no functools.wraps — pytest must see a zero-arg signature,
        # not the original one (it would treat drawn args as fixtures).
        def wrapper():
            # stable per-test seed so failures reproduce across runs
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(N_EXAMPLES):
                fn(*(s.draw(rng) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, fn):
        return fn                      # usable as @settings(...) decorator

    @staticmethod
    def register_profile(name, **kwargs):
        pass

    @staticmethod
    def load_profile(name):
        pass


strategies = sys.modules[__name__]

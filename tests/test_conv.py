"""Implicit-GEMM conv pipeline: kernel bit-exactness (interpret mode) vs
the jnp oracle vs lax.conv_general_dilated, every serving mode, the fused
Collector epilogue, the quantization-domain pass, and the compiled ResNet
path against the pre-refactor dense baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.core.quantize import quantize_int7
from repro.kernels import ops, ref

# k/stride sweep including the odd-spatial 7x7 conv5_x corner (Table I)
GEOMS = [(1, 1), (1, 2), (3, 1), (3, 2), (7, 1), (7, 2)]
SIZES = [(8, 8), (7, 9)]


def _conv_inputs(k, H, W, C=8, n_out=16, seed=0):
    key = jax.random.PRNGKey(seed + 13 * k + H)
    x = jax.random.randint(key, (2, H, W, C), -127, 128, jnp.int8)
    w = jax.random.normal(jax.random.fold_in(key, 1), (C * k * k, n_out)) * 0.1
    return x, quantize_int7(w)


@pytest.mark.parametrize("k,stride", [(3, 1), (3, 2), (7, 2)])
@pytest.mark.parametrize("H,W", SIZES)
def test_im2col_ref_matches_lax_patches(k, stride, H, W):
    """The jnp im2col oracle reproduces conv_general_dilated_patches
    bit-for-bit — the flat weight layout means the same thing on the dense
    (pre-refactor) and implicit-GEMM paths."""
    x, _ = _conv_inputs(k, H, W)
    xf = x.astype(jnp.float32)
    ours = ref.im2col_ref(xf, k, stride)
    lax_p = jax.lax.conv_general_dilated_patches(
        xf, (k, k), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_array_equal(np.asarray(ours), np.asarray(lax_p))


@pytest.mark.parametrize("k,stride", GEOMS)
@pytest.mark.parametrize("H,W", SIZES)
def test_conv_int8_ref_exact(k, stride, H, W):
    """Shift-slice int8 conv oracle == materialized-im2col int32 matmul."""
    x, qt = _conv_inputs(k, H, W)
    acc = ref.conv2d_int8_ref(x, qt.values, k, stride)
    patches = ref.im2col_ref(x.astype(jnp.int32), k, stride)
    want = jnp.einsum("nhwk,ko->nhwo", patches,
                      qt.values.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(want))


@pytest.mark.parametrize("k,stride", GEOMS)
@pytest.mark.parametrize("H,W", SIZES)
def test_conv_implicit_kernel_bit_exact(k, stride, H, W):
    """Pallas implicit-GEMM kernel (interpret mode) == int8 oracle, exactly.

    Accumulators stay below 2^24 so the f32 epilogue output represents the
    int32 accumulator exactly with unit scale.
    """
    x, qt = _conv_inputs(k, H, W)
    n_out = qt.values.shape[1]
    y = ops.conv2d(x, qt.values, k, stride, x_scale=1.0,
                   w_scale=jnp.ones((n_out,)), relu=False)
    acc = ref.conv2d_int8_ref(x, qt.values, k, stride)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(acc).astype(np.float32))


@pytest.mark.parametrize("k,stride", [(1, 1), (3, 1), (3, 2), (7, 2)])
def test_conv_vs_lax_conv_general_dilated(k, stride):
    """Against JAX's own convolution: dequantized implicit-GEMM conv equals
    lax.conv_general_dilated on the dequantized operands."""
    x, qt = _conv_inputs(k, 9, 7)
    C, n_out = 8, qt.values.shape[1]
    s_x = 0.05
    y = ops.conv2d(x, qt.values, k, stride, x_scale=s_x,
                   w_scale=qt.scale.reshape(-1), relu=False)
    w_hwio = (qt.values.astype(jnp.float32) * qt.scale).reshape(
        C, k, k, n_out).transpose(1, 2, 0, 3)
    want = jax.lax.conv_general_dilated(
        x.astype(jnp.float32) * s_x, w_hwio, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_collector_epilogue_matches_separate_ops():
    """scale/BN, bias, shortcut, ReLU fused in the epilogue == the
    separate-XLA-ops sequence the pre-refactor path ran."""
    k, stride = 3, 1
    x, qt = _conv_inputs(k, 8, 8)
    n_out = qt.values.shape[1]
    key = jax.random.PRNGKey(5)
    gamma = jax.random.normal(key, (n_out,))
    beta = jax.random.normal(jax.random.fold_in(key, 1), (n_out,))
    sc = jax.random.normal(jax.random.fold_in(key, 2), (2, 8, 8, n_out))
    s_x = 0.03
    y = ops.conv2d(x, qt.values, k, stride, x_scale=s_x,
                   w_scale=qt.scale.reshape(-1), gamma=gamma, beta=beta,
                   shortcut=sc, relu=True)
    acc = ref.conv2d_int8_ref(x, qt.values, k, stride)
    want = acc.astype(jnp.float32) * (s_x * qt.scale.reshape(1, -1))
    want = want * gamma + beta + sc
    want = jax.nn.relu(want)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_interpret_and_jnp_lowering_agree(monkeypatch):
    """Both REPRO_PALLAS lowerings of the fused conv produce identical int8
    codes under the quantization-domain pass."""
    k, stride = 3, 2
    x, qt = _conv_inputs(k, 9, 7)
    n_out = qt.values.shape[1]
    outs = {}
    for mode in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        outs[mode] = ops.conv2d(x, qt.values, k, stride, x_scale=0.02,
                                w_scale=qt.scale.reshape(-1),
                                gamma=jnp.ones((n_out,)),
                                beta=jnp.zeros((n_out,)), relu=True,
                                quant_out=True)
    np.testing.assert_array_equal(np.asarray(outs["jnp"][0]),
                                  np.asarray(outs["interpret"][0]))
    np.testing.assert_allclose(float(outs["jnp"][1]),
                               float(outs["interpret"][1]), rtol=1e-6)


def test_dense_conv_serving_zero_layout_shuffles(monkeypatch):
    """Acceptance: compile_params stores dense conv leaves spatial-major,
    so ops.conv2d performs ZERO weight-layout shuffles at call time —
    mirroring the sparse zero-unpack spy.  The one permute
    (ref.to_spatial_major) runs at compile time only."""
    leaves = {}
    for mode in ("int8", "cfmm", "bitserial"):
        p = {"w": nn.conv_param(jax.random.PRNGKey(1), 8, 16, 3, 1,
                                ("conv_in", "conv_out"))}
        leaves[mode] = nn.unbox(cl.compile_params(p, mode=mode))["w"]
    calls = {"n": 0}
    real_to, real_from = ref.to_spatial_major, ref.from_spatial_major

    def spy_to(*a, **kw):
        calls["n"] += 1
        return real_to(*a, **kw)

    def spy_from(*a, **kw):
        calls["n"] += 1
        return real_from(*a, **kw)

    monkeypatch.setattr(ref, "to_spatial_major", spy_to)
    monkeypatch.setattr(ref, "from_spatial_major", spy_from)
    x = jax.random.randint(jax.random.PRNGKey(2), (1, 8, 8, 8), -127, 128,
                           jnp.int8)
    for mode, w in leaves.items():
        for lowering in ("jnp", "interpret"):
            monkeypatch.setenv("REPRO_PALLAS", lowering)
            y_q, s_y = cl.apply_conv(w, x, 0.02, quant_out=True)
            assert y_q.dtype == jnp.int8
    assert calls["n"] == 0
    # raw (pre-compile) channel-major codes still pay exactly one permute
    qt = quantize_int7(
        jax.random.normal(jax.random.PRNGKey(3), (8 * 9, 16)) * 0.1)
    ops.conv2d(x, qt.values, 3, 1, x_scale=0.02,
               w_scale=qt.scale.reshape(-1), relu=False)
    assert calls["n"] == 1


def test_spatial_major_roundtrip():
    """to_spatial_major / from_spatial_major invert each other and agree
    with the tap-slab semantics the kernels assume (row = tap*c_in + c)."""
    k, C, n = 3, 5, 4
    codes = jnp.arange(k * k * C * n, dtype=jnp.int32).reshape(k * k * C, n)
    sp = ref.to_spatial_major(codes, k, C)
    np.testing.assert_array_equal(
        np.asarray(ref.from_spatial_major(sp, k, C)), np.asarray(codes))
    # tap slab (dy, dx) in spatial-major == channel-major rows c*k*k + tap
    for dy in range(k):
        for dx in range(k):
            tap = dy * k + dx
            np.testing.assert_array_equal(
                np.asarray(sp[tap * C:(tap + 1) * C]),
                np.asarray(codes[jnp.arange(C) * k * k + tap]))


def test_compiled_conv_carries_geometry():
    """compile_params attaches a static (k, stride, c_in) geom that
    survives nn.unbox and jax.tree operations."""
    p = {"w": nn.conv_param(jax.random.PRNGKey(0), 8, 16, 3, 2,
                            ("conv_in", "conv_out"))}
    for mode in ("int8", "cfmm", "sparse_cfmm", "bitserial"):
        packed = nn.unbox(cl.compile_params(p, mode=mode))
        g = packed["w"]["geom"]
        assert (g.k, g.stride, g.c_in) == (3, 2, 8)
        # childless pytree node: flatten/unflatten round-trips, zero leaves
        leaves, tree = jax.tree.flatten(g)
        assert leaves == [] and jax.tree.unflatten(tree, []) == g


@pytest.mark.parametrize("mode", [m for m in cl.SERVE_MODES if m != "dense"])
def test_apply_conv_all_serve_modes(mode):
    """Every serving mode routes through the implicit-GEMM kernel and lands
    within quantization tolerance of the dense f32 conv."""
    k, stride, C, n_out = 3, 1, 16, 32
    key = jax.random.PRNGKey(2)
    p = {"w": nn.conv_param(key, C, n_out, k, stride,
                            ("conv_in", "conv_out"))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 8, C))
    w_dense = nn.unbox(p)["w"]
    w_hwio = w_dense.reshape(C, k, k, n_out).transpose(1, 2, 0, 3)
    want = jax.lax.conv_general_dilated(
        x, w_hwio, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    packed = nn.unbox(cl.compile_params(p, mode=mode, sparsity=0.5))
    x_q, s_x = cl.act_quant(x)
    y = cl.apply_conv(packed["w"], x_q, s_x, relu=False)
    if mode == "sparse_cfmm":    # pruned weights: subspace only
        # packed_codes un-permutes the kernel's spatial-major bitmap
        # layout back to channel-major patch order
        codes = cl.packed_codes(packed["w"])
        w_pruned = (codes.astype(jnp.float32) * packed["w"]["scale"]).reshape(
            C, k, k, n_out).transpose(1, 2, 0, 3)
        want = jax.lax.conv_general_dilated(
            x, w_pruned, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    rel = float(jnp.linalg.norm(y - want) / jnp.linalg.norm(want))
    assert rel < 0.05, (mode, rel)


def test_int8_and_cfmm_conv_bit_identical():
    k = 3
    key = jax.random.PRNGKey(3)
    p = {"w": nn.conv_param(key, 8, 16, k, 1, ("conv_in", "conv_out"))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 7, 7, 8))
    x_q, s_x = cl.act_quant(x)
    ys = [cl.apply_conv(nn.unbox(cl.compile_params(p, mode=m))["w"],
                        x_q, s_x, relu=True)
          for m in ("int8", "cfmm")]
    np.testing.assert_array_equal(np.asarray(ys[0]), np.asarray(ys[1]))


def test_quant_out_roundtrip():
    """quant_out emits int8 codes + scale whose dequantization matches the
    f32 output to within half a quantization step."""
    k, stride = 3, 1
    x, qt = _conv_inputs(k, 8, 8)
    n_out = qt.values.shape[1]
    kw = dict(x_scale=0.02, w_scale=qt.scale.reshape(-1), relu=True)
    y = ops.conv2d(x, qt.values, k, stride, **kw)
    y_q, s_y = ops.conv2d(x, qt.values, k, stride, quant_out=True, **kw)
    assert y_q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(y_q, np.float32) * float(s_y),
                               np.asarray(y), atol=float(s_y) * 0.5 + 1e-7)


def test_resnet_compiled_matches_dense_path(monkeypatch):
    """End-to-end: the fused implicit-GEMM + quantization-domain ResNet
    agrees with the pre-refactor dense path within quantization tolerance
    (the paper's 0.22% top-1 delta analogue)."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")   # full model: fast lowering
    from repro.models import resnet
    cfg = resnet.ResNetConfig(width_mult=0.25, num_classes=10, in_hw=16)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    want = resnet.apply(nn.unbox(params), x, cfg)
    for mode in ("int8", "cfmm"):
        compiled = nn.unbox(cl.compile_params(params, mode=mode))
        out = resnet.apply(compiled, x, cfg)
        rel = float(jnp.linalg.norm(out - want) / jnp.linalg.norm(want))
        assert rel < 0.15, (mode, rel)
        agree = jnp.mean((jnp.argmax(out, -1) ==
                          jnp.argmax(want, -1)).astype(jnp.float32))
        assert float(agree) == 1.0


def test_resnet_compiled_interpret_mode_small():
    """The compiled ResNet block structure also runs through the Pallas
    kernel in interpret mode (tiny config — interpret is slow)."""
    from repro.models import resnet
    cfg = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 3))
    compiled = nn.unbox(cl.compile_params(params, mode="int8"))
    out = resnet.apply(compiled, x, cfg)
    assert out.shape == (1, 4)
    assert bool(jnp.isfinite(out).all())

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import get_config
from repro.models import moe
from repro.models.layers import ffn


def _setup(top_k=1, n_experts=8):
    import dataclasses
    cfg = get_config("olmoe_1b_7b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, top_k=top_k,
                                     n_experts=n_experts))
    p = nn.unbox(moe.moe_init(jax.random.PRNGKey(0), cfg))
    return cfg, p


def test_top1_equals_selected_expert():
    cfg, p = _setup(top_k=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, cfg.d_model))
    y, aux = moe.moe_forward(p, x, cfg, capacity_factor=8.0)
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    eidx = np.asarray(jnp.argmax(logits, -1))
    for t in range(6):
        w_e = jax.tree.map(lambda a: a[eidx[t]], p["experts"])
        expect = ffn(w_e, x[0, t][None], act=cfg.moe.act)[0]
        np.testing.assert_allclose(np.asarray(y[0, t]), np.asarray(expect),
                                   rtol=2e-3, atol=2e-3)
    assert float(aux["dropped_frac"]) == 0.0


def test_gates_normalized_topk():
    cfg, p = _setup(top_k=2)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    y, aux = moe.moe_forward(p, x, cfg, capacity_factor=8.0)
    assert y.shape == x.shape
    assert float(aux["lb_loss"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_capacity_drops_tokens():
    cfg, p = _setup(top_k=2, n_experts=8)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, cfg.d_model))
    _, aux_tight = moe.moe_forward(p, x, cfg, capacity_factor=0.25)
    _, aux_loose = moe.moe_forward(p, x, cfg, capacity_factor=8.0)
    assert float(aux_tight["dropped_frac"]) > 0.0
    assert float(aux_loose["dropped_frac"]) == 0.0


def test_shared_experts_added():
    import dataclasses
    cfg = get_config("deepseek_v2_lite_16b").reduced()
    p = nn.unbox(moe.moe_init(jax.random.PRNGKey(0), cfg))
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    y, _ = moe.moe_forward(p, x, cfg, capacity_factor=8.0)
    assert y.shape == x.shape


def test_moe_grads_flow_to_router_and_experts():
    cfg, p = _setup(top_k=2)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))

    def loss(p_):
        y, aux = moe.moe_forward(p_, x, cfg)
        return jnp.sum(jnp.square(y)) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["experts"]["up"]))) > 0

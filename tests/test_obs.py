"""Unified telemetry (repro/obs/): metrics registry semantics, Chrome
trace-event export + validator, traced open-loop serving, stage-tick
bubble attribution, and activation-sparsity profiling exactness.

The load-bearing gates (DESIGN.md §11):

* a traced open-loop wave exports VALID Chrome trace JSON with the full
  admission → queue → dispatch → collect span chain for every completed
  request, plus per-stage tick spans on the replica tracks;
* per-stage idle-cause attribution sums EXACTLY to the pipeline's
  ``idle_stage_ticks`` and to ``bubble_fraction`` within float
  tolerance — the attribution is a partition of the bubble, not an
  estimate;
* sparsity histograms match an exact jnp recount of the same rows
  (``reference_profile``), and the profiler's reduction matches a plain
  numpy recount of synthetic aux;
* telemetry is observation-only: logits with profiling on are
  bit-identical to the unprofiled reference.
"""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.kernels import ops
from repro.models import resnet
from repro.obs import Telemetry
from repro.obs.metrics import (LIFE, WAVE, Counter, Gauge, HighWater,
                               Histogram, MetricsRegistry, Reservoir,
                               percentile)
from repro.obs.sparsity import SparsityProfiler
from repro.obs.trace import Trace, main as trace_main, validate_chrome_trace
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.loadgen import poisson_plan, run_open_loop
from repro.serving.pipeline import reference_logits, reference_profile

CFG = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
MB = 2

_params_cache = {}


def _compiled():
    if "int8" not in _params_cache:
        params = resnet.init(jax.random.PRNGKey(0), CFG)
        _params_cache["int8"] = nn.unbox(
            cl.compile_params(params, mode="int8", sparsity=0.5))
    return _params_cache["int8"]


def _images(n, seed=0):
    return np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (n, CFG.in_hw, CFG.in_hw, 3)))


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metric_kinds():
    c = Counter("c")
    c.inc()
    c.inc(3)
    assert c.value == 4
    c.reset()
    assert c.value == 0

    g = Gauge("g", initial=1.5)
    g.set(9.0)
    assert g.value == 9.0
    g.reset()
    assert g.value == 1.5

    hw = HighWater("hw")
    for v in (3, 7, 2):
        hw.observe(v)
    assert hw.value == 7


def test_histogram_percentiles():
    h = Histogram("h", bounds=(1.0, 2.0, 4.0))
    assert h.percentile(50) is None               # empty -> None
    for v in (0.5, 1.5, 1.5, 3.0):
        h.observe(v)
    assert h.counts == [1, 2, 1, 0]
    assert h.total == 4 and h.sum == pytest.approx(6.5)
    # p50: rank 2 lands in the (1, 2] bucket; interpolated inside it
    assert 1.0 <= h.percentile(50) <= 2.0
    h.observe(100.0)                              # overflow bucket...
    assert h.counts[-1] == 1
    assert h.percentile(99) == 4.0                # ...clamps to last bound
    snap = h.snapshot()
    assert snap["total"] == 5 and snap["p50"] is not None


def test_registry_scopes_and_reset_wave():
    m = MetricsRegistry()
    wave_c = m.counter("served")
    life_c = m.counter("odometer", scope=LIFE)
    life_g = m.gauge("row_time", scope=LIFE, initial=None)
    wave_c.inc(5)
    life_c.inc(7)
    life_g.set(0.25)
    assert m.counter("served") is wave_c          # get-or-create
    assert m.wave_names() == ["served"]
    assert set(m.names()) == {"served", "odometer", "row_time"}
    m.reset_wave()
    assert wave_c.value == 0                      # wave zeroed
    assert life_c.value == 7 and life_g.value == 0.25   # life survives
    snap = m.snapshot()
    assert snap == {"served": 0, "odometer": 7, "row_time": 0.25}
    assert "served" in m and "missing" not in m
    with pytest.raises(AssertionError):           # kind mismatch is a bug
        m.gauge("served")


def test_sparsity_profiler_matches_numpy_recount():
    """Feed synthetic post-ReLU activations through the profiler's aux
    contract and check every reduced number against a direct numpy
    recount of the same activations."""
    rng = np.random.RandomState(0)
    groups, n, hw, c = 4, 3, 2, 8
    prof = SparsityProfiler(groups=groups, hist_buckets=4)
    acts = []
    for _ in range(2):                            # two microbatches
        a = np.maximum(rng.randn(n, hw, hw, c), 0.0)
        acts.append(a)
        z = (a == 0.0)
        zg = z.reshape(n, hw, hw, c // groups, groups)
        prof.add({"layer0": {
            "row_zeros": z.reshape(n, -1).sum(1).astype(np.float32),
            "group_zeros": zg.sum((0, 1, 2, 4)).astype(np.float32),
            "group_allzero": zg.all(4).sum((0, 1, 2)).astype(np.float32),
            "elems_per_row": np.float32(hw * hw * c),
            "cells": np.float32(n * hw * hw),
        }})
    snap = prof.snapshot()
    assert snap["microbatches_profiled"] == 2
    lay = snap["layers"]["layer0"]
    allz = np.concatenate(acts)                   # (2n, hw, hw, c)
    zeros = float((allz == 0.0).sum())
    elems = allz.size
    assert lay["n_rows"] == 2 * n
    assert lay["zeros"] == zeros
    assert lay["zero_fraction"] == pytest.approx(zeros / elems)
    fr = (allz == 0.0).reshape(2 * n, -1).mean(1)
    ref_hist, _ = np.histogram(fr, bins=np.linspace(0, 1, 5))
    assert lay["row_fraction_hist"]["counts"] == [int(x) for x in ref_hist]
    zg = (allz == 0.0).reshape(2 * n, hw, hw, c // groups, groups)
    ref_group = zg.sum((0, 1, 2, 4)) / (elems / (c // groups))
    np.testing.assert_allclose(lay["group_zero_fraction"], ref_group)
    ref_cells = zg.all(4).sum((0, 1, 2)) / (2 * n * hw * hw)
    np.testing.assert_allclose(lay["group_allzero_cell_fraction"],
                               ref_cells)
    assert snap["overall_zero_fraction"] == pytest.approx(zeros / elems)


# ---------------------------------------------------------------------------
# trace export + validator
# ---------------------------------------------------------------------------

def _fake_clock(times):
    it = iter(times)
    last = [0.0]

    def clock():
        try:
            last[0] = next(it)
        except StopIteration:
            pass
        return last[0]
    return clock


def test_trace_export_nests_and_validates():
    tr = Trace(clock=_fake_clock([0.0]))
    tr.name_process(1, "replica0")
    tr.name_thread(1, 0, "stage0")
    tr.span("outer", "t", 1, 0, 0.001, 0.009)
    tr.span("inner", "t", 1, 0, 0.002, 0.005)     # nested inside outer
    tr.instant("edge", "t", 1, 0, t=0.004, bytes=128)
    obj = tr.to_chrome_trace()
    assert validate_chrome_trace(obj) == []
    phs = [(e["ph"], e["name"]) for e in obj["traceEvents"]]
    assert phs[:2] == [("M", "process_name"), ("M", "thread_name")]
    # stack discipline: outer B, inner B, inner E (or instant), outer E
    names = [e["name"] for e in obj["traceEvents"] if e["ph"] in "BE"]
    assert names == ["outer", "inner", "inner", "outer"]
    assert obj["otherData"]["dropped_events"] == 0


def test_trace_buffer_bounded_and_still_valid():
    tr = Trace(capacity=2, clock=_fake_clock([0.0]))
    for i in range(5):
        tr.span(f"s{i}", "t", 0, 0, i * 0.01, i * 0.01 + 0.005)
    assert len(tr.spans) == 2 and tr.dropped == 3
    obj = tr.to_chrome_trace()
    assert validate_chrome_trace(obj) == []       # whole spans dropped,
    assert obj["otherData"]["dropped_events"] == 3  # never orphaned B/E


def test_validator_rejects_broken_traces():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": []}) != []
    ev = {"name": "a", "ph": "B", "ts": 1.0, "pid": 0, "tid": 0}
    assert any("missing keys" in e for e in validate_chrome_trace(
        {"traceEvents": [{"ph": "B"}]}))
    assert any("unclosed" in e for e in validate_chrome_trace(
        {"traceEvents": [ev]}))
    assert any("no open B" in e for e in validate_chrome_trace(
        {"traceEvents": [dict(ev, ph="E")]}))
    bad_order = [dict(ev, ts=5.0), dict(ev, ph="E", ts=6.0),
                 dict(ev, name="b", ts=1.0),
                 dict(ev, name="b", ph="E", ts=2.0)]
    assert any("not monotonic" in e for e in validate_chrome_trace(
        {"traceEvents": bad_order}))


def test_trace_cli_validates_files(tmp_path):
    tr = Trace(clock=_fake_clock([0.0]))
    tr.span("a", "t", 0, 0, 0.0, 0.001)
    good = tr.save(tmp_path / "good.json")
    assert trace_main([str(good)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [
        {"name": "a", "ph": "E", "ts": 0.0, "pid": 0, "tid": 0}]}))
    assert trace_main([str(bad)]) == 1
    assert trace_main([]) == 2
    # and as the CLI CI actually runs
    r = subprocess.run([sys.executable, "-m", "repro.obs.trace",
                        str(good)], capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# traced serving: open-loop wave -> valid trace with the full span chain
# ---------------------------------------------------------------------------

def test_traced_open_loop_wave_full_span_chain(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    tel = Telemetry(trace=True)
    fe = ResNetFrontend(CFG, _compiled(), mode="int8", n_replicas=2,
                        n_stages=2, microbatch=MB, telemetry=tel)
    fe.run([FrontendRequest(rid=-1, images=_images(2))])     # warmup
    plan = poisson_plan(rate_rps=400.0, n_requests=6,
                        image_pool=_images(4, seed=1),
                        size_mix=((1, 2.0), (2, 1.0)), seed=0)
    res = run_open_loop(fe, plan, max_wall_s=60.0)
    done = [a.req for a in plan if a.req.done]
    assert res["admitted"] == len(plan) and len(done) == len(plan)

    path = tel.trace.save(tmp_path / "wave.json")
    obj = json.loads(open(path).read())
    assert validate_chrome_trace(obj) == []
    spans_by_rid, stage_spans, arrivals = {}, 0, set()
    for e in obj["traceEvents"]:
        if e["ph"] == "B" and e.get("cat") == "request":
            spans_by_rid.setdefault(e["tid"], set()).add(e["name"])
        if e["ph"] == "B" and e.get("cat") == "pipeline":
            stage_spans += 1
            assert e["name"].startswith("stage")
        if e["ph"] == "i" and e["name"] == "arrival":
            arrivals.add(e["args"]["rid"])
    chain = {"admission", "queue", "dispatch", "collect"}
    for req in done:
        assert spans_by_rid.get(req.rid) == chain, (req.rid, spans_by_rid)
    assert arrivals == {a.req.rid for a in plan}
    assert stage_spans > 0
    # replica process/thread names are in the metadata
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert "frontend" in names and any("replica" in n for n in names)


# ---------------------------------------------------------------------------
# bubble attribution
# ---------------------------------------------------------------------------

def test_bubble_attribution_partitions_bubble_fraction(monkeypatch):
    """Per-stage idle-cause counts are a PARTITION of the pipeline's
    idle stage-ticks: they sum to ``idle_stage_ticks`` exactly and to
    ``bubble_fraction * n_stages * ticks`` within float tolerance, on
    every replica, for a wave long enough to contain fill, drain, and
    host-gap ticks."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled(), mode="int8", n_replicas=2,
                        n_stages=2, microbatch=MB)
    reqs = [FrontendRequest(rid=i, images=_images(2, seed=i))
            for i in range(6)]
    fe.run(reqs)
    st = fe.stats()
    for rs in st["replicas"]:
        attr = rs["bubble_attribution"]
        assert sorted(attr) == ["drain", "fill", "host", "starved"]
        S = rs["n_stages"]
        assert all(len(v) == S for v in attr.values())
        total = sum(sum(v) for v in attr.values())
        assert total == rs["idle_stage_ticks"]
        launches = sum(rs["stage_launches"])
        assert total == S * rs["ticks"] - launches
        assert total == pytest.approx(
            rs["bubble_fraction"] * S * rs["ticks"])


# ---------------------------------------------------------------------------
# sparsity profiling through the fleet vs the jnp recount oracle
# ---------------------------------------------------------------------------

def test_fleet_sparsity_matches_reference_profile(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    groups = 4
    tel = Telemetry(trace=True, sparsity_groups=groups)
    fe = ResNetFrontend(CFG, _compiled(), mode="int8", n_replicas=1,
                        n_stages=2, microbatch=MB, telemetry=tel)
    x = _images(6, seed=3)
    reqs = [FrontendRequest(rid=i, images=x[i * MB:(i + 1) * MB])
            for i in range(3)]
    fe.run(reqs)
    # observation-only: logits bit-identical to the unprofiled reference
    got = np.concatenate([np.asarray(r.logits) for r in reqs])
    ref = np.asarray(reference_logits(_compiled(), CFG, x, MB))
    np.testing.assert_array_equal(got, ref)

    served = tel.sparsity.snapshot()
    _, oracle = reference_profile(_compiled(), CFG, x, MB, groups,
                                  lowering="jnp")
    assert served["microbatches_profiled"] == 3
    assert served["layers"].keys() == oracle["layers"].keys()
    exact = ops._mode() == "jnp"
    for name, a in served["layers"].items():
        b = oracle["layers"][name]
        assert a["n_rows"] == b["n_rows"] == 6
        if exact:
            assert a["zeros"] == b["zeros"], name
            assert (a["row_fraction_hist"]["counts"]
                    == b["row_fraction_hist"]["counts"]), name
            assert a["group_zero_fraction"] == b["group_zero_fraction"]
            assert (a["group_allzero_cell_fraction"]
                    == b["group_allzero_cell_fraction"]), name
        else:
            np.testing.assert_allclose(a["zero_fraction"],
                                       b["zero_fraction"], atol=1e-5)
    assert 0.0 < served["overall_zero_fraction"] < 1.0


# ---------------------------------------------------------------------------
# the registry behind the frontend: snapshot + structural reset audit
# ---------------------------------------------------------------------------

def test_frontend_snapshot_and_reset_wave_audit(monkeypatch):
    """The reset_stats audit, structurally: every wave-scoped metric in
    the door + engine registries zeroes on ``reset_stats`` and every
    life-scoped one survives — checked against the registry's own scope
    declarations rather than a hand-kept list of attributes."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled(), mode="int8", n_replicas=2,
                        n_stages=2, microbatch=MB)
    fe.run([FrontendRequest(rid=i, images=_images(2, seed=i))
            for i in range(4)])
    snap = fe.snapshot()
    assert set(snap) == {"door", "replicas"}
    assert snap["door"]["door.requests_done"] == 4
    assert sum(snap["door"][f"door.replica{r}.rows_dispatched"]
               for r in range(2)) == 8
    assert any(n.startswith("pipe.stage0.idle.")
               for n in snap["replicas"][0])

    life_before = {
        n: fe.metrics.get(n).snapshot()
        for n in fe.metrics.names() if fe.metrics.get(n).scope == LIFE}
    assert life_before["door.row_time_s"] is not None   # EWMA warmed
    fe.reset_stats()
    after = fe.snapshot()
    for name in fe.metrics.wave_names():
        m = fe.metrics.get(name)
        v = after["door"][name]
        if m.kind == "counter":
            assert v == 0, name
        elif m.kind == "reservoir":
            assert v["count"] == 0 and v["p50"] is None, name
    for eng_snap, eng in zip(after["replicas"], fe.replicas):
        for name in eng.metrics.wave_names():
            if eng.metrics.get(name).kind == "counter":
                assert eng_snap[name] == 0, name
    for name, v in life_before.items():                 # life survives
        assert after["door"][name] == v, name
    st = fe.stats()
    assert st["requests_done"] == 0 and st["latency_p50_s"] is None
    assert st["est_row_time_s"] is not None             # odometer kept

"""Failure x recovery matrix for the serving fleet (serving/faults.py,
the frontend watchdog + requeue, and SLO-aware admission).

The contract under test: a replica can die (fail-stop), wedge (hang), or
degrade (slow) at ANY point in a request's life — before its rows
dispatch, mid-pipeline, or on the last tick — and every affected request
still completes with logits BIT-IDENTICAL to
``serving.pipeline.reference_logits``, because per-row quantization
domains make re-execution exact (DESIGN.md §9/§10).  Plus: no orphaned
row spans anywhere (engine queues and inlets drained), failure/requeue
accounting, replica re-admission, the open-loop load generator, and the
typed shed path.

Hang-injection cells burn ``watchdog_ticks`` no-progress steps per cell,
so they carry the ``chaos`` marker (pytest.ini) and run in CI's slow
tier; the kill cells are the acceptance gate and stay in tier-1.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import compiled_linear as cl
from repro.models import resnet
from repro.serving.faults import Fault, FaultInjector, ReplicaFailure
from repro.serving.frontend import (Admitted, FrontendRequest, Rejected,
                                    ResNetFrontend)
from repro.serving.loadgen import (offered_rows_per_s, poisson_plan,
                                   run_open_loop)
from repro.serving.pipeline import reference_logits

CFG = resnet.ResNetConfig(width_mult=0.125, num_classes=4, in_hw=8)
MB = 2

_params_cache = {}


def _compiled(mode="int8"):
    if mode not in _params_cache:
        params = resnet.init(jax.random.PRNGKey(0), CFG)
        _params_cache[mode] = nn.unbox(
            cl.compile_params(params, mode=mode, sparsity=0.5))
    return _params_cache[mode]


def _images(n, seed=1):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                        (n, CFG.in_hw, CFG.in_hw, 3)))


_ref_cache = {}


def _reference(images, microbatch=MB):
    key = (microbatch, os.environ.get("REPRO_PALLAS"), images.tobytes())
    if key not in _ref_cache:
        _ref_cache[key] = np.asarray(reference_logits(
            _compiled(), CFG, jnp.asarray(images), microbatch))
    return _ref_cache[key]


def _check_refs(reqs, microbatch=MB):
    for r in reqs:
        assert r.done, r.rid
        np.testing.assert_array_equal(
            np.asarray(r.logits), _reference(r.images, microbatch))


def _assert_drained(fe):
    """No orphaned _RowSpans anywhere: every engine queue empty, every
    stage inlet empty, all row accounting at zero — on failed AND
    healthy replicas — and the door holds nothing."""
    for eng in fe.replicas:
        assert not eng.queue, eng.queue
        assert eng.pending_rows == 0 == eng._scan_pending_rows()
        assert not eng.pipe.busy
    assert not fe.queue and not fe._requeue and not fe._inflight
    assert fe._door_rows == fe._scan_door_rows() == 0


def _wave(base, n_reqs=4, rows=MB):
    """mb-aligned traffic (rows == microbatch) so every injected
    microbatch is full — requeue after a failure then never changes a
    microbatch SHAPE, keeping even the Pallas lowerings bit-exact."""
    x = _images(n_reqs * rows)
    return [FrontendRequest(rid=base + i, images=x[i * rows:(i + 1) * rows])
            for i in range(n_reqs)]


def _fleet(pack, n_stages, **kw):
    kw.setdefault("watchdog_ticks", 4)
    return ResNetFrontend(CFG, _compiled(), mode="int8", n_replicas=2,
                          n_stages=n_stages, microbatch=MB,
                          continuous=pack, **kw)


def _healthy_ticks(fe, base):
    """Drive one healthy wave and report replica 0's productive tick
    count — the faulted waves use the same deterministic traffic, so
    tick i of the twin run is step i of the fault schedule."""
    reqs = _wave(base)
    fe.run(reqs)
    _check_refs(reqs)
    return fe.replicas[0].pipe.ticks


def _run_fault_cell(fe, inj, fault, base):
    """Arm ``fault`` on replica 0, drive a fresh wave, assert the
    recovery contract, then heal the fleet for the next cell."""
    inj.arm(fe.replicas[0], fault)
    reqs = _wave(base)
    fe.reset_stats()
    fe.run(reqs)
    _check_refs(reqs)
    _assert_drained(fe)
    st = fe.stats()
    assert st["replicas_failed"] == 1 and st["failed"] == [True, False], st
    assert st["requeues"] >= 1 and st["rows_requeued"] >= 1, st
    assert st["rows_dispatched"][1] >= st["rows_requeued"], st
    inj.disarm(fe.replicas[0])
    fe.restart_replica(0)
    return st


# ---------------------------------------------------------------------------
# The failure matrix: kind x timing x packing x stages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_stages", (1, 2))
@pytest.mark.parametrize("pack", (True, False))
def test_kill_matrix(monkeypatch, pack, n_stages):
    """Fail-stop at {before dispatch, mid-pipeline, last tick}: every
    request completes bit-identical to the never-failed reference, no
    spans orphaned, requeue accounted."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(pack, n_stages)
    inj = FaultInjector()
    ticks = _healthy_ticks(fe, base=0)
    timings = {"before": 0, "mid": max(1, ticks // 2),
               "last": max(1, ticks - 1)}
    for i, (name, at) in enumerate(timings.items()):
        _run_fault_cell(fe, inj, Fault("kill", at_step=at),
                        base=100 * (i + 1))


@pytest.mark.chaos
@pytest.mark.parametrize("n_stages", (1, 2))
@pytest.mark.parametrize("pack", (True, False))
def test_hang_matrix(monkeypatch, pack, n_stages):
    """Wedge (no exception, no progress) at the same three timings: the
    progress watchdog fails the replica after ``watchdog_ticks`` stalled
    steps and the requeue contract holds identically."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(pack, n_stages)
    inj = FaultInjector()
    ticks = _healthy_ticks(fe, base=0)
    timings = {"before": 0, "mid": max(1, ticks // 2),
               "last": max(1, ticks - 1)}
    for i, (name, at) in enumerate(timings.items()):
        st = _run_fault_cell(fe, inj, Fault("hang", at_step=at),
                             base=100 * (i + 1))
        assert "watchdog" in st["failures"][0]["reason"], st["failures"]


def test_slow_replica_limps_to_completion(monkeypatch):
    """A replica degraded to 1/3 rate stays under the watchdog threshold:
    it is NOT failed, and its share of the work completes (slowly) with
    exact logits."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1, watchdog_ticks=8)
    inj = FaultInjector()
    inj.arm(fe.replicas[0], Fault("slow", at_step=0, slow_factor=3))
    reqs = _wave(0)
    fe.run(reqs)
    _check_refs(reqs)
    _assert_drained(fe)
    st = fe.stats()
    assert st["replicas_failed"] == 0 and st["requeues"] == 0, st


def test_slow_replica_past_watchdog_is_failed(monkeypatch):
    """A replica degraded past the watchdog threshold is
    indistinguishable from a wedge — failed, drained, requeued; the
    threshold is exactly the boundary between the two slow tests."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1, watchdog_ticks=4)
    inj = FaultInjector()
    inj.arm(fe.replicas[0], Fault("slow", at_step=0, slow_factor=50))
    reqs = _wave(0)
    fe.run(reqs)
    _check_refs(reqs)
    _assert_drained(fe)
    st = fe.stats()
    assert st["replicas_failed"] == 1 and st["rows_requeued"] >= 1, st


def test_kill_requeue_interpret(monkeypatch):
    """The recovery path through the Pallas kernels in interpret mode:
    microbatch=1 keeps every executable shape fixed, so requeued rows
    are bit-identical even across the failure (the kernel-tier CI cell;
    the jnp matrix above covers the schedule space)."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    fe = ResNetFrontend(CFG, _compiled(), mode="int8", n_replicas=2,
                        n_stages=1, microbatch=1, watchdog_ticks=4)
    inj = FaultInjector()
    inj.arm(fe.replicas[0], Fault("kill", at_step=1))
    reqs = [FrontendRequest(rid=i, images=_images(1, seed=i))
            for i in range(4)]
    fe.run(reqs)
    _check_refs(reqs, microbatch=1)
    _assert_drained(fe)
    st = fe.stats()
    assert st["replicas_failed"] == 1 and st["rows_requeued"] >= 1, st


# ---------------------------------------------------------------------------
# Re-admission, guards, accounting
# ---------------------------------------------------------------------------

def test_restart_replica_rejoins_the_fleet(monkeypatch):
    """After kill + restart, the replica serves again: fresh engine,
    fresh device placement, same shared host tree, rows routed to BOTH
    replicas on the next wave."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1)
    inj = FaultInjector()
    inj.arm(fe.replicas[0], Fault("kill", at_step=0))
    old_engine = fe.replicas[0]
    fe.run(_wave(0))
    assert fe.failed[0]
    fe.restart_replica(0)
    assert fe.replicas[0] is not old_engine
    assert fe.replicas[0].params is fe.params      # shared host tree
    assert not fe.failed[0]
    fe.reset_stats()
    reqs = _wave(100)
    fe.run(reqs)
    _check_refs(reqs)
    st = fe.stats()
    assert all(n > 0 for n in st["rows_dispatched"]), st
    assert st["replicas_failed"] == 0


def test_restart_live_replica_requeues_its_work(monkeypatch):
    """Restarting a HEALTHY mid-flight replica (e.g. a planned rolling
    update) drains and requeues what it holds — nothing is lost and the
    logits stay exact."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 2)
    fe.run(_wave(0))                               # warm/compile
    reqs = _wave(100)
    for r in reqs:
        fe.submit(r)
    fe.step()                                      # rows now in flight
    assert any(eng.pending_rows for eng in fe.replicas)
    fe.restart_replica(0)
    while fe.step():
        pass
    _check_refs(reqs)
    _assert_drained(fe)


def test_all_replicas_failed_raises_diagnosable(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1)
    inj = FaultInjector()
    for eng in fe.replicas:
        inj.arm(eng, Fault("kill", at_step=0))
    with pytest.raises(RuntimeError, match="all 2 replicas failed") as ei:
        fe.run(_wave(0))
    assert ei.value.fleet_stats["replicas_failed"] == 2


def test_run_max_steps_timeout_attaches_stats(monkeypatch):
    """The last-resort escape: with the watchdog disabled, a wedged
    replica turns `while step()` into a diagnosable TimeoutError with
    the fleet stats attached — never an infinite loop."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = ResNetFrontend(CFG, _compiled(), mode="int8", n_replicas=1,
                        n_stages=1, microbatch=MB, watchdog_ticks=None)
    inj = FaultInjector()
    inj.arm(fe.replicas[0], Fault("hang", at_step=0))
    with pytest.raises(TimeoutError, match="max_steps=25") as ei:
        fe.run(_wave(0), max_steps=25)
    st = ei.value.fleet_stats
    assert st["replicas_failed"] == 0 and st["door_rows"] >= 0
    assert st["watchdog_ticks"] is None


def test_watchdog_no_false_positive_at_threshold_one(monkeypatch):
    """A healthy busy replica changes its progress marker on EVERY step
    (the inlet occupancy pattern shifts even when row counts hold), so
    even watchdog_ticks=1 never fails a healthy fleet — the threshold
    buys hang detection, not flakiness."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 2, watchdog_ticks=1)
    reqs = [FrontendRequest(rid=i, images=_images(1 + i % 3, seed=i))
            for i in range(6)]
    fe.run(reqs)
    assert fe.stats()["replicas_failed"] == 0, fe.stats()["failures"]
    for r in reqs:
        assert r.done


def test_door_rows_counter_matches_scan_through_failure(monkeypatch):
    """The O(1) door backlog counter the admission estimate reads must
    equal its linear-scan oracle at every step of a kill-recovery run
    (requeued spans flow through the same accounting)."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1, admit_rows=3)
    fe.run(_wave(0))                               # warm
    inj = FaultInjector()
    inj.arm(fe.replicas[0], Fault("kill", at_step=2))
    reqs = [FrontendRequest(rid=100 + i, images=_images(1 + i % 4, seed=i))
            for i in range(6)]
    for r in reqs:
        fe.submit(r)
        assert fe._door_rows == fe._scan_door_rows()
    while True:
        try:
            busy = fe.step()
        finally:
            assert fe._door_rows == fe._scan_door_rows()
        if not busy:
            break
    for r in reqs:
        assert r.done
    _assert_drained(fe)


def test_fault_injector_disarm_restores(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1)
    eng = fe.replicas[0]
    inj = FaultInjector()
    inj.arm(eng, Fault("kill", at_step=0))
    assert "step" in eng.__dict__                  # instance-level wrap
    with pytest.raises(ReplicaFailure):
        eng.step()
    inj.disarm(eng)
    assert "step" not in eng.__dict__              # class method restored
    assert eng.step() is False                     # idle engine, no raise
    inj.disarm(eng)                                # idempotent
    with pytest.raises(AssertionError):
        Fault("explode")
    with pytest.raises(AssertionError):
        Fault("slow", slow_factor=1)


# ---------------------------------------------------------------------------
# SLO-aware admission (deterministic: seeded service-rate estimate)
# ---------------------------------------------------------------------------

def test_slo_admission_sheds_typed_outcome(monkeypatch):
    """With a p95 budget set and a measured service rate, a request
    whose estimated wait (backlog x per-row time) exceeds the budget is
    shed with a typed ``Rejected`` — never queued — while requests under
    budget keep flowing; without a budget nothing is ever shed."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1, admit_rows=2)
    fe._row_time = 0.1                             # seeded calibration
    fe.slo_p95_s = 1.0
    x = _images(12)
    r0 = FrontendRequest(rid=0, images=x[:4])
    r1 = FrontendRequest(rid=1, images=x[4:8])
    shed = FrontendRequest(rid=2, images=x[8:12])
    out0 = fe.submit(r0)
    assert isinstance(out0, Admitted)
    assert out0.estimated_wait_s == pytest.approx(0.4)
    out1 = fe.submit(r1)
    assert isinstance(out1, Admitted)              # 0.8s, still under
    out2 = fe.submit(shed)                         # 1.2s > 1.0 budget
    assert isinstance(out2, Rejected)
    assert out2.estimated_wait_s == pytest.approx(1.2)
    assert out2.slo_p95_s == 1.0 and out2.reason == "p95-budget"
    assert shed.rejected and not shed.done
    assert shed.rid not in fe._live and len(fe.queue) == 2
    st = fe.stats()
    assert st["rejected"] == 1 and st["rejected_rows"] == 4
    # the admitted requests drain normally and exactly; the shed one can
    # be resubmitted once the backlog clears
    while fe.step():
        pass
    _check_refs([r0, r1])
    fe.slo_p95_s = None
    out3 = fe.submit(shed)
    assert isinstance(out3, Admitted) and not shed.rejected
    while fe.step():
        pass
    _check_refs([shed])


def test_slo_none_or_uncalibrated_always_admits(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1)
    assert fe._row_time is None
    fe.slo_p95_s = 1e-9                            # absurd budget, no data
    out = fe.submit(FrontendRequest(rid=0, images=_images(2)))
    assert isinstance(out, Admitted)               # cannot shed w/o evidence
    assert out.estimated_wait_s is None
    while fe.step():
        pass
    fe.slo_p95_s = None
    fe._row_time = 10.0                            # huge, but no budget set
    out = fe.submit(FrontendRequest(rid=1, images=_images(2)))
    assert isinstance(out, Admitted)
    while fe.step():
        pass


def test_reset_service_rate_and_survival_across_reset_stats(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1)
    fe.run(_wave(0))
    assert fe._row_time is not None
    fe.reset_stats()
    assert fe._row_time is not None                # calibration survives
    assert fe.stats()["est_row_time_s"] == fe._row_time
    fe.reset_service_rate()
    assert fe._row_time is None


# ---------------------------------------------------------------------------
# Open-loop load generation
# ---------------------------------------------------------------------------

def test_poisson_plan_deterministic_and_shaped():
    pool = _images(8)
    mix = ((1, 0.75), (2, 0.25))
    p1 = poisson_plan(rate_rps=50, n_requests=20, image_pool=pool,
                      size_mix=mix, seed=7)
    p2 = poisson_plan(rate_rps=50, n_requests=20, image_pool=pool,
                      size_mix=mix, seed=7)
    assert [a.t for a in p1] == [a.t for a in p2]
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a.req.images, b.req.images)
    sizes = {len(a.req.images) for a in p1}
    assert sizes <= {1, 2} and 1 in sizes
    assert all(p1[i].t < p1[i + 1].t for i in range(len(p1) - 1))
    assert offered_rows_per_s(p1) > 0
    # rids unique and offset
    rids = [a.req.rid for a in poisson_plan(rate_rps=1, n_requests=3,
                                            image_pool=pool, seed=0,
                                            rid_base=100)]
    assert rids == [100, 101, 102]


def test_open_loop_conservation_and_exactness(monkeypatch):
    """Open-loop replay: every offered request is either admitted (and
    completes bit-identical to its reference) or typed-rejected —
    admitted + rejected == offered, nothing silently dropped."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1)
    pool = _images(8)
    # warm BOTH microbatch shapes (full and 1-row) so compile time never
    # lands inside the measured open-loop wave
    fe.run([FrontendRequest(rid=-2, images=pool[:MB]),
            FrontendRequest(rid=-1, images=pool[:1])])
    fe.reset_service_rate()
    fe.run([FrontendRequest(rid=-3, images=pool[:MB])])
    cap_rows_s = 1.0 / fe._row_time
    fe.reset_stats()
    plan = poisson_plan(rate_rps=0.5 * cap_rows_s / 1.25, n_requests=8,
                        image_pool=pool, size_mix=((1, 3), (2, 1)), seed=3)
    res = run_open_loop(fe, plan, max_wall_s=120)
    assert res["admitted"] + res["rejected"] == res["offered"] == 8
    assert res["rejected"] == 0                    # no SLO set
    assert res["latency_p95_s"] >= res["latency_p50_s"] > 0
    for r in res["admitted_requests"]:
        assert r.done
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      _reference(r.images))


def test_open_loop_overload_sheds_under_slo(monkeypatch):
    """At a 16x-capacity open-loop burst with a tight p95 budget, the
    admission controller sheds (typed) rather than queueing without
    bound, and every admitted request still completes exactly."""
    monkeypatch.setenv("REPRO_PALLAS", "jnp")
    fe = _fleet(True, 1)
    pool = _images(8)
    fe.run([FrontendRequest(rid=-2, images=pool[:MB]),
            FrontendRequest(rid=-1, images=pool[:1])])
    fe.reset_service_rate()
    fe.run([FrontendRequest(rid=-3, images=pool[:MB])])
    cap_rows_s = 1.0 / fe._row_time
    fe.slo_p95_s = 10 * fe._row_time
    fe.reset_stats()
    plan = poisson_plan(rate_rps=16 * cap_rows_s / 1.25, n_requests=16,
                        image_pool=pool, size_mix=((1, 3), (2, 1)), seed=5)
    res = run_open_loop(fe, plan, max_wall_s=120)
    assert res["admitted"] + res["rejected"] == 16
    assert res["rejected"] > 0, res
    assert fe.stats()["rejected"] == res["rejected"]
    for r in res["admitted_requests"]:
        assert r.done
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      _reference(r.images))
    for r in res["rejected_requests"]:
        assert r.rejected and r.logits is None

"""Model-zoo serving bench — every zoo member through the DAG-general
compile path (DESIGN.md §12).

Per model (resnet50 / mobilenet_v2 / repvgg_a0): wall-clock im/s through
a 2-stage PipelineEngine *and* through the ResNetFrontend on top of it,
each gated on bit-identity against the model's own ``reference_logits``;
the int8 resident weight bytes vs the f32 dense parameter bytes (the
constant-parameter compression story, now per-architecture); and for
RepVGG the fused-vs-unfused dense forward speedup — the payoff of the
compile-time branch fold (3x3 + 1x1 + identity collapse into one 3x3, so
the fused graph runs one conv per block where the training graph ran
three).  Results append to BENCH_models.json.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro import nn
from repro.core.compiled_linear import compile_params
from repro.models import mobilenet_v2 as mb
from repro.models import repvgg, resnet
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.pipeline import PipelineEngine, reference_logits

N_STAGES = 2


def _best_of(fn, iters=3):
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _f32_bytes(params) -> int:
    return int(sum(np.asarray(v).size * 4
                   for v in jax.tree.leaves(nn.unbox(params))))


def _zoo(full: bool):
    """(cfg, boxed servable params, extras) per model at bench scale."""
    width, hw_r, hw_z, n_img, mbatch = ((0.25, 64, 64, 8, 2) if full
                                        else (0.25, 32, 32, 4, 2))
    if os.environ.get("REPRO_PALLAS") == "interpret" and not full:
        # CI's kernel-tier smoke runs this bench in interpret mode
        # (python-rate kernels): shrink so the trajectory stays populated
        width, hw_r, hw_z, n_img, mbatch = 0.125, 8, 16, 2, 1
    zoo = {}
    r_cfg = resnet.ResNetConfig(width_mult=width, num_classes=10,
                                in_hw=hw_r)
    zoo["resnet50"] = (r_cfg, r_cfg.init(jax.random.PRNGKey(0)), {})
    m_cfg = mb.MobileNetV2Config(width_mult=width, num_classes=10,
                                 in_hw=hw_z)
    zoo["mobilenet_v2"] = (m_cfg, m_cfg.init(jax.random.PRNGKey(0)), {})
    v_cfg = repvgg.RepVGGConfig(width_mult=width, num_classes=10,
                                in_hw=hw_z)
    unfused = v_cfg.init(jax.random.PRNGKey(0))
    zoo["repvgg_a0"] = (v_cfg, v_cfg.fuse(unfused), {"unfused": unfused})
    return zoo, n_img, mbatch


def run(full=False):
    zoo, n_img, mbatch = _zoo(full)
    out = {"config": dict(images=n_img, microbatch=mbatch,
                          n_stages=N_STAGES)}
    for name, (cfg, raw, extras) in zoo.items():
        compiled = nn.unbox(compile_params(raw, mode="int8"))
        x = np.asarray(jax.random.normal(
            jax.random.PRNGKey(1), (n_img, cfg.in_hw, cfg.in_hw, 3)))
        ref = np.asarray(reference_logits(compiled, cfg,
                                          jax.numpy.asarray(x), mbatch))

        eng = PipelineEngine(cfg, compiled, mode="int8",
                             n_stages=N_STAGES, microbatch=mbatch)
        got = eng.run_batch(x)                 # warmup: compiles stages
        np.testing.assert_array_equal(np.asarray(got), ref)
        wall = _best_of(lambda: eng.run_batch(x), iters=2)
        st = eng.stats()

        fe = ResNetFrontend(cfg, compiled, mode="int8", n_replicas=1,
                            n_stages=N_STAGES, microbatch=mbatch)
        req = FrontendRequest(rid=0, images=x)
        fe.run([req])
        assert req.done
        np.testing.assert_array_equal(np.asarray(req.logits), ref)
        fe_wall = _best_of(lambda: fe.run(
            [FrontendRequest(rid=0, images=x)]), iters=2)

        int8_bytes = int(sum(st["stage_weight_bytes"]))
        f32_bytes = _f32_bytes(raw)
        row = {
            "in_hw": cfg.in_hw,
            "pipeline_im_s": n_img / wall,
            "frontend_im_s": n_img / fe_wall,
            "weight_bytes_int8": int8_bytes,
            "weight_bytes_f32": f32_bytes,
            "weight_ratio_f32_over_int8": f32_bytes / int8_bytes,
            "n_conv_blocks": sum(len(b) for b in st["stage_blocks"]),
            "planned_link_bytes": st["planned_link_bytes"],
        }
        if "unfused" in extras:
            # fused-vs-unfused dense forward: the branch-fold payoff.
            # Interleave the two measurements over fresh jit instances
            # (best-of minima) so machine drift hits both alike — the
            # same discipline pipeline_bench._stage_times and
            # telemetry_bench use; a sequential pair measured here was
            # 30% noisy when other bench sections' compile threads
            # were still draining
            xb = jax.numpy.asarray(x)
            pf, pu = nn.unbox(raw), nn.unbox(extras["unfused"])
            t_f = t_u = float("inf")
            for _ in range(2):
                fwd = jax.jit(lambda p, v: cfg.apply(p, v))
                for p in (pf, pu):             # compile + warm both
                    jax.block_until_ready(fwd(p, xb))
                for _ in range(4):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fwd(pf, xb))
                    t1 = time.perf_counter()
                    jax.block_until_ready(fwd(pu, xb))
                    t_f = min(t_f, t1 - t0)
                    t_u = min(t_u, time.perf_counter() - t1)
            row["fused_ms"] = t_f * 1e3
            row["unfused_ms"] = t_u * 1e3
            row["fused_speedup"] = t_u / t_f
        out[name] = row
        extra = (f" | fused {row['fused_speedup']:.2f}x vs 3-branch"
                 if "fused_speedup" in row else "")
        print(f" {name:13s} ({cfg.in_hw}x{cfg.in_hw}): pipeline "
              f"{row['pipeline_im_s']:7.1f} im/s | frontend "
              f"{row['frontend_im_s']:7.1f} im/s | weights f32/int8 "
              f"{row['weight_ratio_f32_over_int8']:.2f}x{extra}; "
              f"bit-identical to reference")
    assert out["repvgg_a0"]["fused_speedup"] > 1.0, out["repvgg_a0"]
    return out

"""Serving throughput benchmark: tokens/s on the continuous-batching
engine across compiled-weight modes (tiny model; CPU numbers are relative
signals, the roofline table carries the TPU projections)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.launch.train import build_cfg
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def run(full=False):
    cfg = build_cfg("smollm_360m", "tiny")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    n_req = 8 if full else 4
    out = {}
    for mode in ("dense", "int8"):
        engine = ServingEngine(cfg, params, mode=mode, batch_slots=4,
                               max_seq=64)
        reqs = [Request(rid=i, prompt=list(rng.randint(1, cfg.vocab, 12)),
                        max_new_tokens=12) for i in range(n_req)]
        engine.run(reqs[:1])          # warm up compile
        reqs = [Request(rid=i, prompt=list(rng.randint(1, cfg.vocab, 12)),
                        max_new_tokens=12) for i in range(n_req)]
        t0 = time.time()
        engine.run(reqs)
        dt = time.time() - t0
        toks = sum(len(r.tokens_out) for r in reqs)
        out[mode] = {"tok_s": toks / dt, "tokens": toks, "wall_s": dt}
        print(f" mode={mode:6s} {toks} tokens @ {toks / dt:7.1f} tok/s")
    return out

"""Telemetry bench — the observability layer's cost and exactness gates
(DESIGN.md §11), recorded to BENCH_telemetry.json.

Three sections:

* **Overhead** — the same closed-loop wave through four fleets sharing
  one compiled tree: *base* (``telemetry=None``), *off* (a ``Telemetry``
  object attached but with tracing and sparsity profiling disabled — the
  cost of the ``is None`` guards and lifecycle stamps), *trace* (span
  tracing + metrics on), and *profiled* (tracing + activation-sparsity
  profiling).  Timed rounds are INTERLEAVED across the fleets and the
  best-of minimum per fleet is compared, so machine drift hits every
  fleet alike and the minima are stable where single-shot CPU timings
  are not.  Gates: telemetry-off within **2%** of base, tracing within
  **10%**.  The profiled fleet's overhead is recorded but not gated —
  sparsity profiling adds real per-layer zero-count compute to every
  conv launch (observation-only for the *logits*, not for the clock),
  so a wall-clock budget there would gate the model size, not the
  telemetry.
* **Bit-identity** — every request's logits from all four fleets are
  bit-identical to ``reference_logits`` and to each other: tracing reads
  timestamps and sparsity profiling reads the f32 Collector output that
  already exists, so observation never perturbs the computation.
* **Sparsity exactness** — the profiled fleet's accumulated activation
  histograms are compared against ``reference_profile``'s exact jnp
  recount of the same rows: zero counts and per-image histogram buckets
  must match EXACTLY when serving ran the jnp lowering (CPU default);
  under a Pallas lowering the comparison is on fractions to 1e-5 (the
  only divergence channel is a pre-activation value within one ulp of
  0.0 crossing the ReLU boundary differently between lowerings).

Plus the trace-schema gate: the profiled fleet's Chrome trace export passes
``repro.obs.trace.validate_chrome_trace`` and contains the full
admission → queue → dispatch → stage-tick → collect span chain for
every completed request.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro import nn
from repro.core.compiled_linear import compile_params
from repro.kernels import ops
from repro.models import resnet
from repro.obs import Telemetry
from repro.obs.trace import validate_chrome_trace
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.pipeline import reference_logits, reference_profile

OFF_BUDGET = 0.02          # telemetry-off overhead gate vs base
TRACE_BUDGET = 0.10        # tracing+metrics overhead gate vs base
GROUPS = 8                 # coarse_in lane-group size profiled


def _wave(x, mb, rid_base=0):
    return [FrontendRequest(rid=rid_base + i, images=x[i:i + mb])
            for i in range(0, len(x), mb)]


def run(full=False):
    width, hw, n_img, mb, iters = ((0.25, 32, 32, 2, 8) if full
                                   else (0.125, 16, 32, 2, 8))
    if os.environ.get("REPRO_PALLAS") == "interpret" and not full:
        width, hw, n_img, mb, iters = 0.125, 8, 8, 2, 4
    cfg = resnet.ResNetConfig(width_mult=width, num_classes=100, in_hw=hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    compiled = nn.unbox(compile_params(params, mode="int8", sparsity=0.8))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (n_img, hw, hw, 3)))
    kw = dict(mode="int8", n_replicas=2, n_stages=2, microbatch=mb)
    print(f" telemetry overhead + exactness ({hw}x{hw}, width {width}, "
          f"{n_img} images, best-of-{iters} interleaved):")

    tel = Telemetry(trace=True, sparsity_groups=GROUPS)
    fleets = {
        "base": ResNetFrontend(cfg, compiled, **kw),
        "off": ResNetFrontend(cfg, compiled, telemetry=Telemetry(), **kw),
        "trace": ResNetFrontend(cfg, compiled,
                                telemetry=Telemetry(trace=True), **kw),
        "profiled": ResNetFrontend(cfg, compiled, telemetry=tel, **kw),
    }
    logits = {}
    for name, fe in fleets.items():
        fe.run(_wave(x, mb))                   # warmup: compiles replicas
        reqs = _wave(x, mb, rid_base=100)
        fe.run(reqs)                           # the exactness wave
        logits[name] = np.concatenate([np.asarray(r.logits)
                                       for r in reqs])

    # interleave the timed rounds across the fleets — rotating the order
    # each round so no fleet always inherits another's cache state — and
    # compare best-of minima, which are stable against machine drift
    # (CPU frequency, background load) where single shots are not
    walls = {name: float("inf") for name in fleets}
    order = list(fleets)
    for it in range(iters):
        for name in order[it % len(order):] + order[:it % len(order)]:
            reqs = _wave(x, mb, rid_base=1000 * (it + 1))
            t0 = time.perf_counter()
            fleets[name].run(reqs)
            walls[name] = min(walls[name], time.perf_counter() - t0)

    # -- bit-identity: observation never perturbs the computation ------
    ref = np.asarray(reference_logits(compiled, cfg, x, mb))
    for name, lg in logits.items():
        np.testing.assert_array_equal(lg, ref, err_msg=name)

    # -- overhead gates (on best-of minima) ----------------------------
    over_off = walls["off"] / walls["base"] - 1.0
    over_trace = walls["trace"] / walls["base"] - 1.0
    over_profiled = walls["profiled"] / walls["base"] - 1.0
    assert over_off <= OFF_BUDGET, (
        f"telemetry-off overhead {over_off:.1%} exceeds "
        f"{OFF_BUDGET:.0%} budget", walls)
    assert over_trace <= TRACE_BUDGET, (
        f"tracing overhead {over_trace:.1%} exceeds "
        f"{TRACE_BUDGET:.0%} budget", walls)
    print(f"   wall best-of-{iters}: base {walls['base'] * 1e3:.1f} ms | "
          f"off {walls['off'] * 1e3:.1f} ms ({over_off:+.1%}) | trace "
          f"{walls['trace'] * 1e3:.1f} ms ({over_trace:+.1%}) | profiled "
          f"{walls['profiled'] * 1e3:.1f} ms ({over_profiled:+.1%}, "
          f"ungated); logits bit-identical across all four")

    # -- sparsity exactness vs the jnp recount oracle ------------------
    # the profiled fleet served warmup + exactness + iters timed waves
    # of the same pool: every row of x was profiled (2 + iters) times,
    # so the oracle is the same pool repeated — counts are additive
    reps = 2 + iters
    pool = np.concatenate([x] * reps)
    served = tel.sparsity.snapshot()
    _, oracle = reference_profile(compiled, cfg, pool, mb, GROUPS,
                                  lowering="jnp")
    exact = ops._mode() == "jnp"
    for lay, a in served["layers"].items():
        b = oracle["layers"][lay]
        assert a["n_rows"] == b["n_rows"], (lay, a["n_rows"], b["n_rows"])
        if exact:
            assert a["zeros"] == b["zeros"], (lay, a["zeros"], b["zeros"])
            assert (a["row_fraction_hist"]["counts"]
                    == b["row_fraction_hist"]["counts"]), lay
            assert (a["group_zero_fraction"] == b["group_zero_fraction"]
                    ), lay
        else:
            np.testing.assert_allclose(
                a["zero_fraction"], b["zero_fraction"], atol=1e-5,
                err_msg=lay)
            np.testing.assert_allclose(
                a["group_zero_fraction"], b["group_zero_fraction"],
                atol=1e-5, err_msg=lay)
    print(f"   sparsity: {len(served['layers'])} layers over "
          f"{served['microbatches_profiled']} microbatches, overall "
          f"post-ReLU zero fraction {served['overall_zero_fraction']:.3f}"
          f" — {'EXACT match' if exact else 'fractions to 1e-5'} vs the "
          f"jnp recount oracle")

    # -- trace schema + per-request span chain -------------------------
    obj = tel.trace.to_chrome_trace()
    errs = validate_chrome_trace(obj)
    assert not errs, errs[:5]
    spans_by_rid = {}
    for e in obj["traceEvents"]:
        if e["ph"] == "B" and e.get("cat") == "request":
            spans_by_rid.setdefault(e["tid"], set()).add(e["name"])
    chain = {"admission", "queue", "dispatch", "collect"}
    assert spans_by_rid and all(v == chain for v in spans_by_rid.values()
                                ), spans_by_rid
    stage_spans = sum(1 for e in obj["traceEvents"]
                      if e["ph"] == "B" and e.get("cat") == "pipeline")
    assert stage_spans > 0
    print(f"   trace: {len(obj['traceEvents'])} events valid; full "
          f"span chain for {len(spans_by_rid)} requests, {stage_spans} "
          f"stage-tick spans")

    on_stats = fleets["profiled"].stats()
    return {
        "config": dict(width_mult=width, in_hw=hw, images=n_img,
                       microbatch=mb, iters=iters, groups=GROUPS),
        "wall_s": walls,
        "overhead_off": over_off,
        "overhead_trace": over_trace,
        "overhead_profiled": over_profiled,
        "budgets": {"off": OFF_BUDGET, "trace": TRACE_BUDGET},
        "logits_bit_identical": True,
        "sparsity": {
            "exact_vs_oracle": exact,
            "overall_zero_fraction": served["overall_zero_fraction"],
            "microbatches_profiled": served["microbatches_profiled"],
            "layers_profiled": len(served["layers"]),
            "top_zero_layers": dict(sorted(
                ((k, v["zero_fraction"])
                 for k, v in served["layers"].items()),
                key=lambda kv: -kv[1])[:5]),
        },
        "trace": {
            "events": len(obj["traceEvents"]),
            "valid": True,
            "requests_with_full_chain": len(spans_by_rid),
            "stage_tick_spans": stage_spans,
            "dropped_events": obj["otherData"]["dropped_events"],
        },
        "bubble_attribution": [rs["bubble_attribution"]
                               for rs in on_stats["replicas"]],
    }


if __name__ == "__main__":
    run()

"""Pipeline-parallel serving bench — the executed Fig 7.

For n_stages in {1, 2, 4}: wall-clock im/s through the rotating
microbatch schedule, measured bubble fraction, measured int8 bytes per
inter-stage edge (vs the StagePlan's analytic link bytes), per-stage
resident weight bytes (the persistent property), and the *pipeline-law*
steady-state rate ``microbatch / max(stage step time)`` — the number that
scales with stage count.  On this single-core container the stages
time-share one device, so wall-clock im/s stays flat while the
pipeline-law rate shows what a one-device-per-stage deployment sustains
(each stage's step shrinks as the network splits); both are recorded to
BENCH_pipeline.json so the trajectory keeps the distinction honest.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro import nn
from repro.core.compiled_linear import compile_params
from repro.models import resnet
from repro.serving.pipeline import PipelineEngine, reference_logits

STAGE_COUNTS = (1, 2, 4)


def _best_of(fn, iters=3):
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _stage_times(eng, jit_instances=2, iters=3):
    """Per-stage steady-state step time on the sample inputs the schedule
    recorded: best-of over FRESH jit instances of each stage program, not
    the engine's first one — the first jit instance of a program measures
    ~2x slow in this container even after warmup, which made the
    pipeline-law walltime assert flaky (same fix kernel_bench._time got
    in PR 1)."""
    times = []
    for stage, carry in zip(eng.pipe.stages, eng.pipe.sample_inputs):
        if carry is None:                      # stage never saw a microbatch
            continue
        raw = getattr(stage.fn, "__wrapped__", stage.fn)
        best = float("inf")
        for _ in range(jit_instances):
            jitted = jax.jit(raw)
            fn = lambda: jax.block_until_ready(jitted(stage.params, carry))
            fn()                               # compile + warm this instance
            best = min(best, _best_of(fn, iters=iters))
        times.append(best)
    return times


def run(full=False):
    width, hw, n_img, mb = (0.25, 64, 16, 2) if full else (0.25, 32, 8, 2)
    modes = ("int8", "sparse_cfmm") if full else ("int8",)
    if os.environ.get("REPRO_PALLAS") == "interpret" and not full:
        # CI's kernel-tier smoke drives the bench through Pallas
        # interpret mode (python-rate execution): shrink the sweep so the
        # trajectory stays populated without blowing the job budget
        width, hw, n_img, mb = 0.125, 16, 4, 2
    cfg = resnet.ResNetConfig(width_mult=width, num_classes=100, in_hw=hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (n_img, hw, hw, 3)))
    out = {"config": dict(width_mult=width, in_hw=hw, images=n_img,
                          microbatch=mb),
           "modes": {}}
    for mode in modes:
        compiled = nn.unbox(compile_params(params, mode=mode, sparsity=0.8))
        ref = np.asarray(reference_logits(compiled, cfg,
                                          jax.numpy.asarray(x), mb))
        rows = {}
        print(f" pipeline serving, mode={mode} ({hw}x{hw}, width {width}, "
              f"{n_img} images, microbatch {mb}):")
        for n_stages in STAGE_COUNTS:
            eng = PipelineEngine(cfg, compiled, mode=mode,
                                 n_stages=n_stages, microbatch=mb)
            got = eng.run_batch(x)             # warmup: compiles stages
            np.testing.assert_array_equal(np.asarray(got), ref)
            wall = _best_of(lambda: eng.run_batch(x), iters=2)
            st = eng.stats()
            stage_t = _stage_times(eng)
            pipeline_im_s = mb / max(stage_t)
            rows[str(n_stages)] = {
                "wall_im_s": n_img / wall,
                "pipeline_im_s": pipeline_im_s,
                "stage_step_ms": [t * 1e3 for t in stage_t],
                "bubble_fraction": st["bubble_fraction"],
                "edge_int8_bytes_per_image": [
                    e["int8_bytes"] // mb for e in st["edge_bytes"]],
                "planned_link_bytes": st["planned_link_bytes"],
                "stage_weight_bytes": st["stage_weight_bytes"],
                "stage_blocks": st["stage_blocks"],
            }
            assert rows[str(n_stages)]["edge_int8_bytes_per_image"] == \
                st["planned_link_bytes"], rows[str(n_stages)]
            print(f"   {n_stages} stage(s): wall {n_img / wall:7.1f} im/s | "
                  f"pipeline-law {pipeline_im_s:7.1f} im/s "
                  f"(bottleneck step {max(stage_t) * 1e3:.1f} ms) | "
                  f"bubble {st['bubble_fraction']:.2f} | edges "
                  f"{rows[str(n_stages)]['edge_int8_bytes_per_image']} B/img")
        # the point of pipelining: the bottleneck stage shrinks as the
        # network splits, so the steady-state rate scales with stages
        scaling = rows["4"]["pipeline_im_s"] / rows["1"]["pipeline_im_s"]
        rows["pipeline_scaling_4_over_1"] = scaling
        print(f"   pipeline-law scaling 4-stage/1-stage: {scaling:.2f}x; "
              f"outputs bit-identical to the single-device path")
        assert scaling > 1.2, rows
        out["modes"][mode] = rows
    return out

"""Replicated-pipeline front-end bench — the fleet behind one front door.

Five sweeps, all recorded to BENCH_frontend.json:

* **Replica scaling** (n_replicas in {1, 2, 4}, one stage chain each):
  measured wall-clock im/s through the shared admission queue next to the
  *replica-law* aggregate rate ``n_replicas x chain rate``.  Every fleet
  runs the IDENTICAL chain program, so the chain's steady-state rate
  ``microbatch / bottleneck stage step`` is measured once (best-of over
  fresh jit instances, the PR 1 kernel_bench fix) and scaled by the
  replica count — each fleet's own per-replica measurements are recorded
  alongside so drift would show.  On this single-core container the
  replicas time-share one device so wall im/s stays flat; the replica-law
  number is what a one-device-group-per-replica deployment sustains, and
  the analytic/measured pair keeps the trajectory honest exactly like
  BENCH_pipeline.json does for stages.
* **Offered load** (fixed 2 replicas): p50/p95 wall-clock request
  latency and max queue depth as the number of concurrently submitted
  requests grows — the front door, not the kernels, is where load shows
  up first.
* **Continuous batching** (fixed 2 replicas, every request ONE row —
  the heavy-small-traffic mix): microbatch occupancy and p50/p95 request
  latency with cross-request packing on (``continuous=True``, the
  default: per-row quantization domains let rows from different requests
  share a microbatch, DESIGN.md §9) vs the whole-request baseline
  (``continuous=False``), at the same offered load.  The gate: packed
  occupancy >= 1.5x the baseline's, p95 no worse.
* **Fault tolerance** (the 2-replica fleet): kill 1 of 2 replicas
  mid-flight (``serving.faults.FaultInjector``).  Gates: every request
  still completes, logits BIT-identical to the no-failure reference
  (per-row quantization domains make the requeued re-execution exact,
  DESIGN.md §10), exactly one replica failed with >= 1 requeued span,
  goodput degrades no worse than proportionally (loose band for
  container noise), and after ``restart_replica`` the fleet serves on
  both replicas again with zero failures.
* **Open loop** (same fleet, recovered): ``serving.loadgen`` Poisson
  arrivals with a 3:1 small/large request mix replayed in wall time at
  {0.5, 2, 16}x the fleet's measured row capacity — the
  latency-vs-offered-load curve plus shed fraction.  The SLO-aware
  admission gates: a generous p95 budget at low load sheds NOTHING,
  a tight budget under 16x overload sheds SOMETHING (typed
  ``Rejected``), and every admitted request completes exactly.

Every run first asserts the fleet's logits are bit-identical to
``serving.pipeline.reference_logits`` per request.  (One carve-out: the
continuous-batching wave under ``REPRO_PALLAS=interpret`` checks to
float tolerance instead — packing 1-row requests into 2-row microbatches
compares executables of different batch shapes, which the compiled
lowerings only guarantee to FMA-contraction ulps; the jnp lowering is
bit-exact across shapes and is asserted as such.)
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.compiled_linear import compile_params
from repro.models import resnet
from repro.serving.faults import Fault, FaultInjector
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.loadgen import (offered_rows_per_s, poisson_plan,
                                   run_open_loop)
from repro.serving.pipeline import reference_logits

from benchmarks.pipeline_bench import _best_of, _stage_times

REPLICA_COUNTS = (1, 2, 4)


def _requests(x, rows_per_req):
    return [FrontendRequest(rid=i, images=x[i:i + rows_per_req])
            for i in range(0, len(x), rows_per_req)]


def _check_fleet(fe, reqs, params, cfg, mb):
    for r in reqs:
        ref = np.asarray(reference_logits(params, cfg,
                                          jnp.asarray(r.images), mb))
        np.testing.assert_array_equal(np.asarray(r.logits), ref)


def run(full=False):
    width, hw, n_img, mb = (0.25, 64, 16, 2) if full else (0.25, 32, 8, 2)
    if os.environ.get("REPRO_PALLAS") == "interpret" and not full:
        # CI's kernel-tier smoke runs this through Pallas interpret mode
        # (python-rate execution): shrink so the trajectory stays
        # populated without blowing the job budget
        width, hw, n_img, mb = 0.125, 16, 4, 2
    cfg = resnet.ResNetConfig(width_mult=width, num_classes=100, in_hw=hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    compiled = nn.unbox(compile_params(params, mode="int8", sparsity=0.8))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (n_img, hw, hw, 3)))
    out = {"config": dict(width_mult=width, in_hw=hw, images=n_img,
                          microbatch=mb),
           "replicas": {}, "offered_load": {}}
    print(f" replicated front-end ({hw}x{hw}, width {width}, {n_img} "
          f"images, microbatch {mb}):")
    fleet2, chain_rate = None, None
    for n_replicas in REPLICA_COUNTS:
        fe = ResNetFrontend(cfg, compiled, mode="int8",
                            n_replicas=n_replicas, n_stages=1,
                            microbatch=mb)
        fe.run(_requests(x, mb))               # warmup: compiles replicas
        fe.reset_stats()
        reqs = _requests(x, mb)
        fe.run(reqs)
        _check_fleet(fe, reqs, compiled, cfg, mb)
        st = fe.stats()                        # exactly the measured wave
        wall = _best_of(lambda: fe.run(_requests(x, mb)), iters=2)
        # replica-law aggregate: every fleet's chains run the IDENTICAL
        # stage program on their own device groups, so the steady-state
        # chain rate is ONE number — measured on the first fleet (best-of
        # fresh jits) and scaled by the replica count; each fleet's own
        # per-replica measurements are recorded alongside
        # (a replica can end up idle when the offered microbatches are
        # fewer than the replicas — e.g. the shrunken interpret config —
        # so only replicas that did work are measured)
        rates = [mb / max(ts) for ts in
                 (_stage_times(eng, iters=5) for eng in fe.replicas)
                 if ts]
        if chain_rate is None:
            chain_rate = max(rates)
        # ground the replica law in falsifiable measurements: with at
        # least one microbatch offered per replica, EVERY replica must
        # have processed rows, and every chain must measure within a
        # loose band of the canonical chain rate — a broken router or a
        # dead/slow replica fails here, where the n x chain_rate
        # projection alone could not catch it
        if n_img // mb >= n_replicas:
            assert all(r > 0 for r in st["rows_dispatched"]), st
            assert len(rates) == n_replicas, (rates, st)
            assert all(0.2 * chain_rate < r < 5.0 * chain_rate
                       for r in rates), (rates, chain_rate)
        # bubble attribution (DESIGN.md §11): which stage and which
        # cause for every idle stage-tick of the measured wave; the
        # per-cause counts must sum to bubble_fraction · S · ticks
        # exactly (the drained-wave identity) on every replica
        for rs in st["replicas"]:
            attr_sum = sum(sum(v) for v in
                           rs["bubble_attribution"].values())
            assert attr_sum == rs["idle_stage_ticks"], (attr_sum, rs)
            total = rs["n_stages"] * rs["ticks"]
            assert abs(attr_sum - rs["bubble_fraction"] * total) < 1e-9, rs
        row = {
            "wall_im_s": n_img / wall,
            "aggregate_im_s": n_replicas * chain_rate,
            "replica_im_s": rates,
            "replica_bubble": st["replica_bubble"],
            "bubble_attribution": [rs["bubble_attribution"]
                                   for rs in st["replicas"]],
            "rows_dispatched": st["rows_dispatched"],
            "max_queue_depth": st["max_queue_depth"],
        }
        out["replicas"][str(n_replicas)] = row
        print(f"   {n_replicas} replica(s): wall {n_img / wall:7.1f} im/s"
              f" | replica-law aggregate {row['aggregate_im_s']:7.1f} "
              f"im/s | rows/replica {st['rows_dispatched']}")
        if n_replicas == 2:
            fleet2 = fe
    # the recorded acceptance metric; it follows from the replica law,
    # so the REAL gates are the per-replica rows/rate asserts above
    scaling = (out["replicas"]["2"]["aggregate_im_s"] /
               out["replicas"]["1"]["aggregate_im_s"])
    out["replicas"]["aggregate_scaling_2_over_1"] = scaling
    print(f"   aggregate scaling 2-replica/1-replica: {scaling:.2f}x; "
          f"outputs bit-identical to the single-device path")
    assert scaling >= 1.8, out["replicas"]

    # offered-load sweep on the 2-replica fleet (engines stay compiled)
    for n_req in (2, 4, 8):
        reqs = [FrontendRequest(rid=i, images=x[(i * mb) % n_img:
                                                (i * mb) % n_img + mb])
                for i in range(n_req)]
        fleet2.reset_stats()
        t0 = time.perf_counter()
        fleet2.run(reqs)
        wall = time.perf_counter() - t0
        st = fleet2.stats()
        out["offered_load"][str(n_req)] = {
            "requests": n_req,
            "wall_s": wall,
            "latency_p50_s": st["latency_p50_s"],
            "latency_p95_s": st["latency_p95_s"],
            "max_queue_depth": st["max_queue_depth"],
        }
        print(f"   load {n_req:2d} reqs: p50 "
              f"{st['latency_p50_s'] * 1e3:7.1f} ms | p95 "
              f"{st['latency_p95_s'] * 1e3:7.1f} ms | max queue depth "
              f"{st['max_queue_depth']}")

    # continuous cross-request batching at a small-request mix: every
    # request is ONE row, so without packing every microbatch runs
    # half-empty (occupancy 1/mb) — exactly the traffic shape the
    # per-row quantization domains were built for
    n_small = n_img
    interp = os.environ.get("REPRO_PALLAS") == "interpret"
    cb = {}
    for continuous, name in ((True, "continuous"), (False, "whole_request")):
        fe = ResNetFrontend(cfg, compiled, mode="int8", n_replicas=2,
                            n_stages=1, microbatch=mb,
                            continuous=continuous)
        mk = lambda: [FrontendRequest(rid=i, images=x[i % n_img:
                                                      i % n_img + 1])
                      for i in range(n_small)]
        warm = mk()
        fe.run(warm)                           # warmup: compiles replicas
        for r in warm:
            ref = np.asarray(reference_logits(compiled, cfg,
                                              jnp.asarray(r.images), mb))
            if interp and continuous:
                # cross-SHAPE comparison (1-row reference vs the 2-row
                # packed microbatch): compiled lowerings guarantee this
                # to FMA-contraction ulps, not bits (the jnp oracle is
                # bit-exact and asserted below)
                np.testing.assert_allclose(np.asarray(r.logits), ref,
                                           rtol=2e-5, atol=1e-6)
            else:
                np.testing.assert_array_equal(np.asarray(r.logits), ref)
        # best-of-4 measured waves to damp scheduler noise (a single
        # cold wave can invert the comparison on this shared container)
        best = None
        for _ in range(4):
            fe.reset_stats()
            reqs = mk()
            t0 = time.perf_counter()
            fe.run(reqs)
            wall = time.perf_counter() - t0
            st = fe.stats()
            occ = [o for o in st["microbatch_occupancy"] if o is not None]
            row = {
                "requests": n_small,
                "rows_per_request": 1,
                "wall_s": wall,
                "latency_p50_s": st["latency_p50_s"],
                "latency_p95_s": st["latency_p95_s"],
                "microbatch_occupancy": sum(occ) / len(occ),
                "mb_injected": sum(s["mb_injected"]
                                   for s in st["replicas"]),
            }
            if best is None or row["latency_p95_s"] < best["latency_p95_s"]:
                best = row
        cb[name] = best
        print(f"   {name:14s}: occupancy "
              f"{best['microbatch_occupancy']:.2f} | mb injected "
              f"{best['mb_injected']:2d} | p95 "
              f"{best['latency_p95_s'] * 1e3:7.1f} ms")
    cb["occupancy_ratio"] = (cb["continuous"]["microbatch_occupancy"] /
                             cb["whole_request"]["microbatch_occupancy"])
    cb["p95_ratio"] = (cb["continuous"]["latency_p95_s"] /
                       cb["whole_request"]["latency_p95_s"])
    out["continuous_batching"] = cb
    print(f"   occupancy ratio {cb['occupancy_ratio']:.2f}x "
          f"(gate >= 1.5) | p95 ratio {cb['p95_ratio']:.2f} "
          f"(gate <= 1.0)")
    # the PR's acceptance gates: packing keeps the pipe >= 1.5x fuller
    # and does not hurt tail latency at the same offered load
    assert cb["occupancy_ratio"] >= 1.5, cb
    assert cb["p95_ratio"] <= 1.0, cb

    # ---- fault tolerance: kill 1 of 2 replicas mid-flight --------------
    # mb-aligned requests so a requeue never changes a microbatch SHAPE:
    # bit-identity holds for every lowering, interpret included
    n_fault = 6 if interp else 8
    mk_fault = lambda base: [
        FrontendRequest(rid=base + i,
                        images=x[(i * mb) % n_img:(i * mb) % n_img + mb])
        for i in range(n_fault)]
    fleet2.reset_stats()
    reqs = mk_fault(1000)
    t0 = time.perf_counter()
    fleet2.run(reqs)
    wall_h = time.perf_counter() - t0
    _check_fleet(fleet2, reqs, compiled, cfg, mb)
    assert fleet2.stats()["replicas_failed"] == 0

    inj = FaultInjector()
    inj.arm(fleet2.replicas[0], Fault("kill", at_step=2))
    fleet2.reset_stats()
    reqs = mk_fault(2000)
    t0 = time.perf_counter()
    fleet2.run(reqs)
    wall_f = time.perf_counter() - t0
    # the acceptance gate: the fleet lost a replica mid-flight and every
    # request still completed BIT-identical to the no-failure reference
    _check_fleet(fleet2, reqs, compiled, cfg, mb)
    st = fleet2.stats()
    assert st["replicas_failed"] == 1 and st["failed"] == [True, False], st
    assert st["requeues"] >= 1 and st["rows_requeued"] >= 1, st
    inj.disarm(fleet2.replicas[0])

    fleet2.restart_replica(0)
    fleet2.reset_stats()
    reqs = mk_fault(3000)
    fleet2.run(reqs)
    _check_fleet(fleet2, reqs, compiled, cfg, mb)
    st3 = fleet2.stats()
    assert st3["replicas_failed"] == 0, st3
    assert all(r > 0 for r in st3["rows_dispatched"]), st3
    goodput_ratio = wall_h / wall_f if wall_f > 0 else None
    out["fault_tolerance"] = {
        "requests": n_fault,
        "kill_at_step": 2,
        "wall_healthy_s": wall_h,
        "wall_killed_s": wall_f,
        "goodput_ratio_killed_over_healthy": goodput_ratio,
        "replicas_failed": st["replicas_failed"],
        "requeues": st["requeues"],
        "rows_requeued": st["rows_requeued"],
        "bit_identical": True,                 # asserted above
        "restart_rows_dispatched": st3["rows_dispatched"],
    }
    print(f"   fault tolerance: kill 1/2 replicas mid-flight -> all "
          f"{n_fault} requests bit-identical | {st['rows_requeued']} rows "
          f"requeued | goodput ratio {goodput_ratio:.2f} | restart "
          f"rebalances {st3['rows_dispatched']}")
    # losing 1 of 2 replicas halves capacity; requeue overhead may cost a
    # little more, scheduler noise a little either way — gate the floor
    assert goodput_ratio >= 0.2, out["fault_tolerance"]

    # ---- open loop: Poisson arrivals vs measured capacity --------------
    # warm the 1-row microbatch shape on BOTH replicas (two 1-row
    # requests route to distinct least-loaded replicas), then calibrate
    # the service rate on steady-state completions only — the EWMA's
    # first samples would otherwise absorb jit compilation, and a
    # mid-wave compile stall reads as a 1000x backlog to the admission
    # estimate (DESIGN.md §10)
    fleet2.run([FrontendRequest(rid=4000, images=x[:1]),
                FrontendRequest(rid=4001, images=x[1:2])])
    fleet2.reset_service_rate()
    fleet2.run(mk_fault(4100))
    row_time = fleet2.stats()["est_row_time_s"]
    cap_rows_s = 1.0 / row_time
    mix = ((1, 3.0), (2, 1.0))                 # mostly-small traffic
    mean_rows = 1.25
    n_ol = 12 if interp else 16
    # per-factor p95 budgets, in units of the measured per-row time: the
    # low-load wave gets a generous budget (gate: sheds NOTHING — no
    # false positives from Poisson burstiness), the 16x overload wave a
    # tight one (gate: sheds SOMETHING rather than queueing unboundedly)
    slo_rows = {0.5: 40.0, 2.0: 40.0, 16.0: 8.0}
    ol = {"capacity_rows_s": cap_rows_s, "est_row_time_s": row_time,
          "requests_per_factor": n_ol, "size_mix": [list(m) for m in mix],
          "factors": {}}
    print(f"   open loop: capacity {cap_rows_s:7.1f} rows/s "
          f"(row time {row_time * 1e3:.2f} ms), {n_ol} requests/factor")
    for factor in (0.5, 2.0, 16.0):
        fleet2.slo_p95_s = slo_rows[factor] * row_time
        fleet2.reset_stats()
        plan = poisson_plan(rate_rps=factor * cap_rows_s / mean_rows,
                            n_requests=n_ol, image_pool=x, size_mix=mix,
                            seed=int(factor * 10))
        res = run_open_loop(fleet2, plan, max_wall_s=600)
        assert res["admitted"] + res["rejected"] == res["offered"] == n_ol
        for r in res["admitted_requests"]:
            ref = np.asarray(reference_logits(compiled, cfg,
                                              jnp.asarray(r.images), mb))
            if interp:
                # the size mix packs 1-row requests into 2-row
                # microbatches: cross-SHAPE, FMA-ulp exact (same
                # carve-out as the continuous-batching wave)
                np.testing.assert_allclose(np.asarray(r.logits), ref,
                                           rtol=2e-5, atol=1e-6)
            else:
                np.testing.assert_array_equal(np.asarray(r.logits), ref)
        row = {
            "offered_rows_s": offered_rows_per_s(plan),
            "slo_p95_s": fleet2.slo_p95_s,
            "admitted": res["admitted"],
            "rejected": res["rejected"],
            "shed_fraction": res["shed_fraction"],
            "goodput_rows_s": res["goodput_rows_s"],
            "latency_p50_s": res["latency_p50_s"],
            "latency_p95_s": res["latency_p95_s"],
            "wall_s": res["wall_s"],
        }
        ol["factors"][str(factor)] = row
        print(f"   open loop {factor:4.1f}x: offered "
              f"{row['offered_rows_s']:7.1f} rows/s | admitted "
              f"{res['admitted']:2d} | shed {res['rejected']:2d} | p95 "
              f"{res['latency_p95_s'] * 1e3:7.1f} ms")
        # SLO admission gates: no false shedding under budget at low
        # load; typed shedding instead of an unbounded queue at 16x
        if factor == 0.5:
            assert res["rejected"] == 0, row
        if factor == 16.0:
            assert res["rejected"] > 0, row
    fleet2.slo_p95_s = None
    out["open_loop"] = ol
    return out

"""Paper Table I — ResNet50 key design parameters, reproduced exactly."""
from repro.models import resnet

PAPER = {
    "conv2_x": dict(channel_count="64/256", hw="56x56", param_count_k=69,
                    total_macs_m=218, mac_per_param=3136),
    "conv3_x": dict(channel_count="128/512", hw="28x28", param_count_k=279,
                    total_macs_m=218, mac_per_param=784),
    "conv4_x": dict(channel_count="256/1024", hw="14x14", param_count_k=1114,
                    total_macs_m=218, mac_per_param=196),
    "conv5_x": dict(channel_count="512/2048", hw="7x7", param_count_k=4456,
                    total_macs_m=218, mac_per_param=49),
}


def run(full=False):
    ours = resnet.table1()
    rows = []
    ok = True
    for stage, want in PAPER.items():
        got = ours[stage]
        # paper truncates 69.6k -> 69; allow the off-by-one rounding
        match = all(got[k] == want[k] or
                    (k == "param_count_k" and abs(got[k] - want[k]) <= 1)
                    for k in want)
        ok &= match
        rows.append((stage, got, match))
        print(f" {stage:9s} {got}  match={match}")
    print(f"Table I reproduction: {'EXACT' if ok else 'MISMATCH'}")
    return {"rows": {s: g for s, g, _ in rows}, "match": ok}

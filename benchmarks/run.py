"""Benchmark harness — one module per paper table/figure plus the
roofline table and kernel micro-benchmarks.

  PYTHONPATH=src python -m benchmarks.run [--full | --smoke]
  PYTHONPATH=src python -m benchmarks.run --report

Outputs land in experiments/bench/ and are summarized to stdout; each
section also *appends* to a BENCH_<name>.json trajectory file at the repo
root ({ts, git, args, result} per run), so perf is tracked across PRs.
--smoke runs a quick subset (used by CI on every push).  --report prints
one line per trajectory file — the headline metric of the latest run,
the git sha it came from, and the delta against the previous entry —
without running anything.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT_DIR = ROOT / "experiments" / "bench"

SMOKE_SECTIONS = ("table1_design_params", "conv", "sparse_conv",
                  "pipeline", "frontend", "telemetry", "models")

# --report headline metric per trajectory (dotted path into `result`);
# sections not listed fall back to the first numeric leaf found
HEADLINES = {
    "conv": "cpu_speedup",
    "sparse_conv": "layers.conv2_x_b 3x3.bits_per_param",
    "pipeline": "modes.int8.pipeline_scaling_4_over_1",
    "frontend": "open_loop.capacity_rows_s",
    "telemetry": "overhead_trace",
    "table1_design_params": "rows.conv2_x.mac_per_param",
    "models": "repvgg_a0.fused_speedup",
}


def _git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              cwd=ROOT, capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _append_trajectory(name: str, entry: dict) -> None:
    path = ROOT / f"BENCH_{name}.json"
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
            assert isinstance(history, list)
        except Exception:
            # never overwrite an unparseable trajectory (e.g. merge
            # conflict markers) — park it and start a fresh history
            bak = path.with_suffix(".json.corrupt")
            path.rename(bak)
            print(f"  ! {path.name} unparseable; preserved as {bak.name}")
            history = []
    history.append(entry)
    path.write_text(json.dumps(history, indent=1, default=str) + "\n")


def _dig(result, path):
    """Resolve a dotted HEADLINES path; None when any hop is missing."""
    cur = result
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def _first_numeric(obj, path=""):
    """Depth-first first numeric leaf — the fallback headline."""
    if isinstance(obj, bool):
        return None, None
    if isinstance(obj, (int, float)):
        return path, obj
    if isinstance(obj, dict):
        for k, v in obj.items():
            p, leaf = _first_numeric(v, f"{path}.{k}" if path else k)
            if leaf is not None:
                return p, leaf
    return None, None


def _headline(name, result):
    path = HEADLINES.get(name)
    if path is not None:
        val = _dig(result, path)
        if val is not None:
            return path.rsplit(".", 1)[-1], val
    return _first_numeric(result)


def report() -> None:
    """One line per BENCH_<name>.json: latest headline, sha, delta."""
    files = sorted(ROOT.glob("BENCH_*.json"))
    if not files:
        print("no BENCH_*.json trajectories at repo root; run "
              "`python -m benchmarks.run --smoke` first")
        return
    for path in files:
        name = path.stem[len("BENCH_"):]
        try:
            history = json.loads(path.read_text())
            assert isinstance(history, list) and history
        except Exception:
            print(f"{name:24s} (unparseable trajectory)")
            continue
        cur = history[-1]
        label, val = _headline(name, cur.get("result", {}))
        if label is None:
            print(f"{name:24s} {len(history)} runs @{cur.get('git', '?')} "
                  f"(no numeric headline)")
            continue
        delta = ""
        # delta vs the most recent PREVIOUS entry carrying this metric
        for prev in reversed(history[:-1]):
            pv = _dig(prev.get("result", {}),
                      HEADLINES.get(name, label)) if HEADLINES.get(
                          name) else _first_numeric(
                              prev.get("result", {}))[1]
            if pv is not None:
                delta = (f"  {'Δ' if pv else ''}"
                         f"{(val - pv) / pv:+.1%} vs {prev.get('git', '?')}"
                         if pv else f"  (prev 0 @{prev.get('git', '?')})")
                break
        print(f"{name:24s} {label} = {val:.4g}  @{cur.get('git', '?')} "
              f"({len(history)} runs){delta}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger kernel sweeps / serving runs")
    ap.add_argument("--smoke", action="store_true",
                    help=f"quick CI subset: {', '.join(SMOKE_SECTIONS)}")
    ap.add_argument("--report", action="store_true",
                    help="summarize BENCH_*.json trajectories (one line "
                         "per bench: headline metric, sha, delta vs "
                         "previous) and exit")
    args = ap.parse_args(argv)
    if args.report:
        report()
        return
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    from benchmarks import fig7, frontend_bench, kernel_bench, \
        models_bench, pipeline_bench, roofline_table, serving_bench, \
        table1, table2, telemetry_bench

    sections = [("table1_design_params", table1.run),
                ("table2_kernel_results", table2.run),
                ("fig7_partitioning", fig7.run),
                ("roofline_40cells", roofline_table.run),
                ("kernel_bench", kernel_bench.run),
                ("conv", kernel_bench.run_conv),
                ("sparse_conv", kernel_bench.run_sparse_conv),
                ("pipeline", pipeline_bench.run),
                ("frontend", frontend_bench.run),
                ("telemetry", telemetry_bench.run),
                ("models", models_bench.run),
                ("serving_bench", serving_bench.run)]
    if args.smoke:
        sections = [s for s in sections if s[0] in SMOKE_SECTIONS]

    t0 = time.time()
    sha = _git_sha()
    results = {}
    for name, fn in sections:
        t = time.time()
        print(f"\n=== {name} ===", flush=True)
        res = fn(full=args.full)
        results[name] = res
        (OUT_DIR / f"{name}.json").write_text(
            json.dumps(res, indent=1, default=str))
        _append_trajectory(name, {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%S"), "git": sha,
            "full": args.full, "smoke": args.smoke, "result": res})
        print(f"[{name}: {time.time() - t:.1f}s]", flush=True)
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s; "
          f"artifacts in {OUT_DIR} + BENCH_<name>.json trajectories")


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure plus the
roofline table and kernel micro-benchmarks.

  PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Outputs land in experiments/bench/ and are summarized to stdout; each
section also *appends* to a BENCH_<name>.json trajectory file at the repo
root ({ts, git, args, result} per run), so perf is tracked across PRs.
--smoke runs a quick subset (used by CI on every push).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT_DIR = ROOT / "experiments" / "bench"

SMOKE_SECTIONS = ("table1_design_params", "conv", "sparse_conv",
                  "pipeline", "frontend")


def _git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              cwd=ROOT, capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _append_trajectory(name: str, entry: dict) -> None:
    path = ROOT / f"BENCH_{name}.json"
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
            assert isinstance(history, list)
        except Exception:
            # never overwrite an unparseable trajectory (e.g. merge
            # conflict markers) — park it and start a fresh history
            bak = path.with_suffix(".json.corrupt")
            path.rename(bak)
            print(f"  ! {path.name} unparseable; preserved as {bak.name}")
            history = []
    history.append(entry)
    path.write_text(json.dumps(history, indent=1, default=str) + "\n")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger kernel sweeps / serving runs")
    ap.add_argument("--smoke", action="store_true",
                    help=f"quick CI subset: {', '.join(SMOKE_SECTIONS)}")
    args = ap.parse_args(argv)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    from benchmarks import fig7, frontend_bench, kernel_bench, \
        pipeline_bench, roofline_table, serving_bench, table1, table2

    sections = [("table1_design_params", table1.run),
                ("table2_kernel_results", table2.run),
                ("fig7_partitioning", fig7.run),
                ("roofline_40cells", roofline_table.run),
                ("kernel_bench", kernel_bench.run),
                ("conv", kernel_bench.run_conv),
                ("sparse_conv", kernel_bench.run_sparse_conv),
                ("pipeline", pipeline_bench.run),
                ("frontend", frontend_bench.run),
                ("serving_bench", serving_bench.run)]
    if args.smoke:
        sections = [s for s in sections if s[0] in SMOKE_SECTIONS]

    t0 = time.time()
    sha = _git_sha()
    results = {}
    for name, fn in sections:
        t = time.time()
        print(f"\n=== {name} ===", flush=True)
        res = fn(full=args.full)
        results[name] = res
        (OUT_DIR / f"{name}.json").write_text(
            json.dumps(res, indent=1, default=str))
        _append_trajectory(name, {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%S"), "git": sha,
            "full": args.full, "smoke": args.smoke, "result": res})
        print(f"[{name}: {time.time() - t:.1f}s]", flush=True)
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s; "
          f"artifacts in {OUT_DIR} + BENCH_<name>.json trajectories")


if __name__ == "__main__":
    main()

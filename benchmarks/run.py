"""Benchmark harness — one module per paper table/figure plus the
roofline table and kernel micro-benchmarks.

  PYTHONPATH=src python -m benchmarks.run [--full]

Outputs land in experiments/bench/ and are summarized to stdout.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

OUT_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "bench"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger kernel sweeps / serving runs")
    args = ap.parse_args(argv)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    from benchmarks import fig7, kernel_bench, roofline_table, serving_bench, \
        table1, table2

    t0 = time.time()
    results = {}
    for name, mod in [("table1_design_params", table1),
                      ("table2_kernel_results", table2),
                      ("fig7_partitioning", fig7),
                      ("roofline_40cells", roofline_table),
                      ("kernel_bench", kernel_bench),
                      ("serving_bench", serving_bench)]:
        t = time.time()
        print(f"\n=== {name} ===", flush=True)
        res = mod.run(full=args.full)
        results[name] = res
        (OUT_DIR / f"{name}.json").write_text(
            json.dumps(res, indent=1, default=str))
        print(f"[{name}: {time.time() - t:.1f}s]", flush=True)
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s; "
          f"artifacts in {OUT_DIR}")


if __name__ == "__main__":
    main()

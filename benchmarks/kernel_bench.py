"""Kernel micro-benchmarks: the compiled-matmul dataflows.

CPU wall-times are sanity signals only (this container has one core); the
meaningful numbers are the analytic TPU-side effective-bandwidth /
effective-TOPs models, which mirror the paper's "effective TOPs"
accounting (sparsity credited as useful work).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiled_linear as cl
from repro.core.quantize import quantize_int7
from repro.kernels import ops
from repro.roofline.analysis import HBM_BW, PEAK_BF16, PEAK_INT8


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(full=False):
    K, N = (4096, 4096) if full else (2048, 1024)
    M_decode = 8
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N)) * 0.05
    qt = quantize_int7(w)
    keep = K // 5 // 8 * 8
    codes = cl.balanced_prune_codes(w, keep).values
    bitmap, values = cl.bitmap_pack(codes, keep)
    x = jax.random.randint(key, (M_decode, K), -127, 128, jnp.int8)
    xf = jax.random.normal(key, (M_decode, K), jnp.bfloat16)

    dense = jax.jit(lambda a, b: a @ b)
    int8mm = jax.jit(lambda a, b: ops.cfmm_matmul(a, b))
    sparse = jax.jit(lambda a, b, v: ops.sparse_cfmm_matmul(a, b, v))

    t_dense = _time(dense, xf, w.astype(jnp.bfloat16))
    t_int8 = _time(int8mm, x, qt.values)
    t_sparse = _time(sparse, x, bitmap, values)
    print(f" decode matvec (M={M_decode}, {K}x{N}) CPU-lowering walltime:")
    print(f"   dense bf16     {t_dense * 1e3:8.2f} ms")
    print(f"   int7 (cfmm)    {t_int8 * 1e3:8.2f} ms")
    print(f"   sparse bitmap  {t_sparse * 1e3:8.2f} ms")

    # analytic TPU model: weight-bound decode (per the paper's effective-ops
    # accounting, zero weights count as useful work)
    bytes_dense = K * N * 2
    bytes_int8 = K * N * 1
    bytes_sparse = bitmap.size + values.size
    t_mem = {m: b / HBM_BW for m, b in [("dense bf16", bytes_dense),
                                        ("int7", bytes_int8),
                                        ("sparse int7", bytes_sparse)]}
    flops = 2 * M_decode * K * N
    print(f"\n TPU v5e analytic decode step ({K}x{N}, batch {M_decode}):")
    for mode, b in [("dense bf16", bytes_dense), ("int7", bytes_int8),
                    ("sparse int7", bytes_sparse)]:
        peak = PEAK_BF16 if mode == "dense bf16" else PEAK_INT8
        t_c = flops / peak
        t_m = b / HBM_BW
        eff_tops = flops / max(t_c, t_m) / 1e12
        print(f"   {mode:12s} weights {b / 1e6:7.2f} MB -> bound "
              f"{max(t_c, t_m) * 1e6:7.2f} us  effective {eff_tops:6.1f} TOP/s "
              f"({'memory' if t_m > t_c else 'compute'}-bound)")
    speedup = bytes_dense / bytes_sparse
    print(f"   sparse-vs-dense effective decode speedup (weight-bound): "
          f"{speedup:.1f}x  — the paper's zero-overhead sparsity, as "
          f"bandwidth")
    return {
        "cpu_ms": {"dense": t_dense * 1e3, "int8": t_int8 * 1e3,
                   "sparse": t_sparse * 1e3},
        "weight_bytes": {"dense": bytes_dense, "int8": bytes_int8,
                         "sparse": int(bytes_sparse)},
        "weight_bound_speedup": float(speedup),
    }

"""Kernel micro-benchmarks: the compiled-matmul dataflows.

CPU wall-times are sanity signals only (this container has one core); the
meaningful numbers are the analytic TPU-side effective-bandwidth /
effective-TOPs models, which mirror the paper's "effective TOPs"
accounting (sparsity credited as useful work).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiled_linear as cl
from repro.core.quantize import quantize_int7
from repro.kernels import ops
from repro.roofline.analysis import HBM_BW, PEAK_BF16, PEAK_INT8


def _time(fn, *args, iters=5):
    # one warmup call (jax.block_until_ready handles tuples/pytrees; the
    # old tuple special-case re-ran fn a second time and skewed jit-cache
    # warmup)
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


# ---------------------------------------------------------------------------
# Convolution: fused implicit-GEMM vs the materialized-im2col baseline
# ---------------------------------------------------------------------------

def _conv_baseline(x, codes, w_scale, gamma, beta, sc, k, stride):
    """The pre-refactor conv chain: materialize f32 im2col patches in HBM,
    dynamic-quantize them, matmul, then separate Collector ops."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    q, s_x = cl.act_quant(patches)
    acc = jax.lax.dot_general(q, codes,
                              dimension_numbers=(((3,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * (s_x * w_scale.reshape(1, -1))
    y = y * gamma + beta + sc
    return jax.nn.relu(y)


def conv_traffic_bytes(hw, c_in, c_out, k, stride, fused, quant_out=False):
    """Analytic per-image HBM *activation* traffic (weights excluded — both
    paths stream the same constant codes).

    Baseline: f32 input read, f32 patch tensor write+read (the k*k-inflated
    im2col buffer), int8 requant write+read, f32 accumulator write, and one
    fused elementwise Collector pass (read y + shortcut, write y).
    Fused:    int8 input read, shortcut read, one f32 (or int8 with the
    quantization-domain pass) output write.
    """
    ho = wo = -(-hw // stride)
    m, patch = ho * wo, c_in * k * k
    out_f32, out_int8 = 4 * m * c_out, m * c_out
    if fused:
        read = hw * hw * c_in + out_f32          # int8 input + shortcut
        write = out_int8 if quant_out else out_f32
        return read + write
    read = (4 * hw * hw * c_in        # f32 input
            + 4 * m * patch           # patches back in for act_quant
            + m * patch               # int8 patches into the matmul
            + out_f32 + out_f32)      # y + shortcut into Collector ops
    write = (4 * m * patch            # materialized f32 patch tensor
             + m * patch              # int8 requantized patches
             + out_f32                # matmul accumulator
             + out_f32)               # Collector output
    return read + write


def _tiled_conv_report(full=False):
    """Row-strip tiling: per-grid-cell VMEM working set (analytic, from
    the strip planner at the real ResNet50 geometries — the 224x224 stem
    and conv2_x) and measured tiled-vs-untiled wall time.

    The VMEM numbers are exact bookkeeping (kernels/tiling.py), not
    timing: whole-image residency = the pre-tiling kernel's cell (padded
    image + weight tile + full-image acc/y rows) vs the planned strip's
    cell.  Wall time compares the strip-looped lowering against the
    untiled one on the conv2_x-shaped jnp path.
    """
    from repro.kernels import ref as kref
    from repro.kernels import tiling

    # (name, hw, c_in, c_out, k, stride) — Table I geometries
    geoms = [("stem_224_k7s2", 224, 3, 64, 7, 2),
             ("conv2_x_56_k3s1", 56, 256, 256, 3, 1)]
    report = {"vmem_budget_bytes": tiling.DEFAULT_VMEM_BUDGET, "layers": {}}
    print(" row-strip tiled conv: per-grid-cell VMEM working set "
          f"(budget {tiling.DEFAULT_VMEM_BUDGET >> 10} kB):")
    for name, hw, c_in, c_out, k, stride in geoms:
        lo, hi, h_out = kref.same_pads(hw, k, stride)
        wp = hw + lo + hi
        bn, _ = ops._tile_pad(c_out, 128)  # the tile the kernel launches
        weight_bytes = k * k * c_in * bn
        kw = dict(k=k, stride=stride, h_out=h_out, w_out=h_out, wp=wp,
                  c_in=c_in, bn=bn, weight_bytes=weight_bytes)
        tiled = tiling.plan_strips(**kw)
        whole = tiling.plan_strips(**kw, strip_h=h_out)
        row = {
            "strip_h": tiled.strip_h, "n_strips": tiled.n_strips,
            "slab_h": tiled.slab_h,
            "x_vmem_bytes": {"whole_image": whole.x_bytes,
                             "strip": tiled.x_bytes},
            "cell_vmem_bytes": {"whole_image": whole.cell_bytes,
                                "strip": tiled.cell_bytes},
            "x_vmem_ratio": whole.x_bytes / tiled.x_bytes,
            "cell_vmem_ratio": whole.cell_bytes / tiled.cell_bytes,
        }
        report["layers"][name] = row
        print(f"   {name:16s} strip_h={tiled.strip_h:3d} "
              f"({tiled.n_strips} strips): x slab "
              f"{whole.x_bytes / 1e3:7.1f} -> {tiled.x_bytes / 1e3:7.1f} kB "
              f"({row['x_vmem_ratio']:.1f}x), cell "
              f"{whole.cell_bytes / 1e6:5.2f} -> "
              f"{tiled.cell_bytes / 1e6:5.2f} MB "
              f"({row['cell_vmem_ratio']:.1f}x)")
    stem = report["layers"]["stem_224_k7s2"]
    assert stem["x_vmem_ratio"] >= 4 and stem["cell_vmem_ratio"] >= 4, stem

    # wall time: tiled vs untiled on a conv2_x-shaped layer
    N, hw, c, k = (2, 56, 256, 3) if full else (1, 28, 128, 3)
    key = jax.random.PRNGKey(1)
    x = jax.random.randint(key, (N, hw, hw, c), -127, 128, jnp.int8)
    qt = quantize_int7(
        jax.random.normal(jax.random.fold_in(key, 1), (c * k * k, c)) * 0.05)
    kw = dict(x_scale=0.02, w_scale=qt.scale.reshape(-1), relu=True)
    strip_h = max(1, hw // 4)
    mk_untiled = lambda: jax.jit(lambda a: ops.conv2d(a, qt.values, k, 1,
                                                      **kw))
    mk_tiled = lambda: jax.jit(lambda a: ops.conv2d(a, qt.values, k, 1,
                                                    strip_h=strip_h, **kw))
    np.testing.assert_array_equal(np.asarray(mk_untiled()(x)),
                                  np.asarray(mk_tiled()(x)))
    # best-of over two FRESH jit instances each: on this single-core
    # container the first executable instance after other bench sections
    # measures up to ~2x slow (allocator warmup), while re-jits of the
    # identical program are steady — min over fresh instances reports the
    # steady state
    t_u = min(_time(mk_untiled(), x), _time(mk_untiled(), x))
    t_t = min(_time(mk_tiled(), x), _time(mk_tiled(), x))
    report["walltime"] = {
        "layer": f"{hw}x{hw}x{c} k{k}s1 (batch {N})", "strip_h": strip_h,
        "cpu_ms": {"untiled": t_u * 1e3, "tiled": t_t * 1e3},
        "tiled_over_untiled": t_t / t_u,
    }
    print(f"   conv2_x-shaped walltime ({hw}x{hw}x{c}, strip_h={strip_h}): "
          f"untiled {t_u * 1e3:.2f} ms vs tiled {t_t * 1e3:.2f} ms "
          f"({t_t / t_u:.2f}x); bit-identical outputs")
    return report


def run_conv(full=False):
    """Fused implicit-GEMM conv vs materialized im2col + separate epilogue:
    CPU wall-time (jnp lowerings of both), the analytic HBM activation-
    traffic model, and the row-strip tiling VMEM/walltime report.
    Persisted by benchmarks/run.py to BENCH_conv.json."""
    N, hw, c, k = (2, 56, 256, 3) if full else (1, 28, 128, 3)
    stride = 1
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (N, hw, hw, c)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (c * k * k, c)) * 0.05
    qt = quantize_int7(w)
    gamma = jax.random.normal(jax.random.fold_in(key, 2), (c,))
    beta = jax.random.normal(jax.random.fold_in(key, 3), (c,))
    sc = jax.random.normal(jax.random.fold_in(key, 4), (N, hw, hw, c))

    baseline = jax.jit(lambda a, s: _conv_baseline(
        a, qt.values, qt.scale.reshape(-1), gamma, beta, s, k, stride))

    def _fused(a, s):
        q, s_x = cl.act_quant(a)
        return ops.conv2d(q, qt.values, k, stride, x_scale=s_x,
                          w_scale=qt.scale.reshape(-1), gamma=gamma,
                          beta=beta, shortcut=s, relu=True)

    fused = jax.jit(_fused)
    t_base = _time(baseline, x, sc)
    t_fused = _time(fused, x, sc)
    layer = f"{hw}x{hw}x{c} k{k}s{stride}"
    print(f" conv {layer} (batch {N}) CPU-lowering walltime:")
    print(f"   im2col + separate epilogue {t_base * 1e3:8.2f} ms")
    print(f"   fused implicit-GEMM        {t_fused * 1e3:8.2f} ms "
          f"({t_base / t_fused:.2f}x)")

    traffic = {}
    for kk in (1, 3, 7):
        b = conv_traffic_bytes(hw, c, c, kk, stride, fused=False)
        f = conv_traffic_bytes(hw, c, c, kk, stride, fused=True)
        fq = conv_traffic_bytes(hw, c, c, kk, stride, fused=True,
                                quant_out=True)
        traffic[f"k{kk}"] = {"baseline": b, "fused_f32": f,
                             "fused_int8": fq, "ratio_f32": b / f,
                             "ratio_int8": b / fq}
        print(f"   k={kk} HBM activation traffic/image: baseline "
              f"{b / 1e6:6.2f} MB vs fused {f / 1e6:6.2f} MB "
              f"({b / f:5.1f}x; {b / fq:5.1f}x with int8 quant-domain out)")
    assert traffic["k3"]["ratio_f32"] >= 5.0, traffic["k3"]
    return {
        "layer": layer, "batch": N,
        "cpu_ms": {"im2col_baseline": t_base * 1e3,
                   "fused_implicit_gemm": t_fused * 1e3},
        "cpu_speedup": t_base / t_fused,
        "hbm_activation_traffic": traffic,
        "tiled": _tiled_conv_report(full),
    }


def run_sparse_conv(full=False):
    """Bitmap-native sparse conv vs the dense-codes implicit-GEMM conv:
    CPU wall-time (jnp lowerings), bit-identity, and the analytic HBM
    *weight* traffic — the (1-s)*8 + 1 bits/param win carried into the
    path that dominates ResNet50.  Persisted to BENCH_sparse_conv.json."""
    from repro import nn
    s = 0.8
    # (layer, c_in, c_out, k, hw): ResNet50 geometries incl. the K=147 stem
    layers = ([("conv2_x_b 3x3", 256, 256, 3, 56), ("stem 7x7", 3, 64, 7, 56)]
              if full else
              [("conv2_x_b 3x3", 128, 128, 3, 28), ("stem 7x7", 3, 64, 7, 28)])
    key = jax.random.PRNGKey(0)
    out = {"sparsity": s, "layers": {}}
    print(f" sparse conv weight traffic at s={s} "
          f"(packed (1-s)*8+1 = {(1 - s) * 8 + 1:.1f} bits/param):")
    for name, c_in, c_out, k, hw in layers:
        p = {"w": nn.conv_param(key, c_in, c_out, k, 1,
                                ("conv_in", "conv_out"))}
        w = nn.unbox(cl.compile_params(p, mode="sparse_cfmm",
                                       sparsity=s))["w"]
        codes = cl.packed_codes(w)
        x = jax.random.randint(jax.random.fold_in(key, 1),
                               (1, hw, hw, c_in), -127, 128, jnp.int8)
        kw = dict(x_scale=0.02, w_scale=w["scale"].reshape(-1), relu=True)
        packed_fn = jax.jit(lambda a: ops.conv2d(
            a, (w["bitmap"], w["values"]), k, 1, **kw))
        dense_fn = jax.jit(lambda a: ops.conv2d(a, codes, k, 1, **kw))
        np.testing.assert_array_equal(np.asarray(packed_fn(x)),
                                      np.asarray(dense_fn(x)))
        t_packed, t_dense = _time(packed_fn, x), _time(dense_fn, x)
        bytes_dense = codes.size                     # int8 codes, 1 B/param
        bytes_packed = int(w["bitmap"].size + w["values"].size)
        ratio = bytes_packed / bytes_dense
        out["layers"][name] = {
            "geometry": f"{c_in}->{c_out} k{k} {hw}x{hw}",
            "weight_bytes_dense_codes": int(bytes_dense),
            "weight_bytes_packed": bytes_packed,
            "ratio_packed_vs_dense": ratio,
            "bits_per_param": bytes_packed * 8 / (c_in * k * k * c_out),
            "cpu_ms": {"dense_codes": t_dense * 1e3,
                       "bitmap_native": t_packed * 1e3},
        }
        print(f"   {name:14s} weights {bytes_dense / 1e3:7.1f} kB dense -> "
              f"{bytes_packed / 1e3:7.1f} kB packed ({ratio:.3f}x, "
              f"{out['layers'][name]['bits_per_param']:.2f} b/param); "
              f"bit-identical outputs")
    r3 = out["layers"][layers[0][0]]["ratio_packed_vs_dense"]
    assert r3 <= 0.35, out    # the 2.6/8 = 0.325 target + keep_k rounding
    return out


def run(full=False):
    K, N = (4096, 4096) if full else (2048, 1024)
    M_decode = 8
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N)) * 0.05
    qt = quantize_int7(w)
    keep = K // 5 // 8 * 8
    codes = cl.balanced_prune_codes(w, keep).values
    bitmap, values = cl.bitmap_pack(codes, keep)
    x = jax.random.randint(key, (M_decode, K), -127, 128, jnp.int8)
    xf = jax.random.normal(key, (M_decode, K), jnp.bfloat16)

    dense = jax.jit(lambda a, b: a @ b)
    int8mm = jax.jit(lambda a, b: ops.cfmm_matmul(a, b))
    sparse = jax.jit(lambda a, b, v: ops.sparse_cfmm_matmul(a, b, v))

    t_dense = _time(dense, xf, w.astype(jnp.bfloat16))
    t_int8 = _time(int8mm, x, qt.values)
    t_sparse = _time(sparse, x, bitmap, values)
    print(f" decode matvec (M={M_decode}, {K}x{N}) CPU-lowering walltime:")
    print(f"   dense bf16     {t_dense * 1e3:8.2f} ms")
    print(f"   int7 (cfmm)    {t_int8 * 1e3:8.2f} ms")
    print(f"   sparse bitmap  {t_sparse * 1e3:8.2f} ms")

    # analytic TPU model: weight-bound decode (per the paper's effective-ops
    # accounting, zero weights count as useful work)
    bytes_dense = K * N * 2
    bytes_int8 = K * N * 1
    bytes_sparse = bitmap.size + values.size
    t_mem = {m: b / HBM_BW for m, b in [("dense bf16", bytes_dense),
                                        ("int7", bytes_int8),
                                        ("sparse int7", bytes_sparse)]}
    flops = 2 * M_decode * K * N
    print(f"\n TPU v5e analytic decode step ({K}x{N}, batch {M_decode}):")
    for mode, b in [("dense bf16", bytes_dense), ("int7", bytes_int8),
                    ("sparse int7", bytes_sparse)]:
        peak = PEAK_BF16 if mode == "dense bf16" else PEAK_INT8
        t_c = flops / peak
        t_m = b / HBM_BW
        eff_tops = flops / max(t_c, t_m) / 1e12
        print(f"   {mode:12s} weights {b / 1e6:7.2f} MB -> bound "
              f"{max(t_c, t_m) * 1e6:7.2f} us  effective {eff_tops:6.1f} TOP/s "
              f"({'memory' if t_m > t_c else 'compute'}-bound)")
    speedup = bytes_dense / bytes_sparse
    print(f"   sparse-vs-dense effective decode speedup (weight-bound): "
          f"{speedup:.1f}x  — the paper's zero-overhead sparsity, as "
          f"bandwidth")
    return {
        "cpu_ms": {"dense": t_dense * 1e3, "int8": t_int8 * 1e3,
                   "sparse": t_sparse * 1e3},
        "weight_bytes": {"dense": bytes_dense, "int8": bytes_int8,
                         "sparse": int(bytes_sparse)},
        "weight_bound_speedup": float(speedup),
    }

"""Paper Table II — corner-kernel implementation results vs the calibrated
cost model (design targets & actuals)."""
from repro.core.fpga_model import TABLE2_ACTUAL, table2_model


def run(full=False):
    t2 = table2_model()
    print(f" calibration: {t2['calibration']}")
    hdr = (f" {'corner':6s} {'fold':>9s} {'inst/kern':>10s} "
           f"{'ALM/kernel':>22s} {'freq MHz':>16s} {'MOPs/ALM':>16s} "
           f"{'GX280 TOPs':>16s} {'GX550 TOPs':>16s}")
    print(hdr)
    for c in ("conv2", "conv5"):
        m, a = t2[c]["model"], t2[c]["actual"]
        print(f" {c:6s} {m['fold']}/{a['folding']:<7d} "
              f"{m['instances_per_kernel']}/{a['instances']:<8d} "
              f"{m['alm_per_kernel'] / 1e3:7.0f}k/{a['alm_per_kernel'] / 1e3:<6.0f}k "
              f"{m['freq_mhz']:7.0f}/{a['freq_mhz']:<7d} "
              f"{m['mops_per_alm']:7.1f}/{a['mops_per_alm']:<7d} "
              f"{m['gx280_tops']:7.1f}/{a['gx280_tops']:<7d} "
              f"{m['gx550_tops']:7.1f}/{a['gx550_tops']:<7d}")
    print(" (model/actual pairs; fold + ALM structure reproduce exactly, "
          "throughput density within ~±35%)")
    return t2

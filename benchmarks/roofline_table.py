"""The 40-cell roofline table: every (arch x shape) on the single-pod
16x16 mesh — analytic (TPU-expected) terms as primary, HLO-CPU-derived
terms from the dry-run artifacts alongside (see DESIGN.md for why the CPU
backend's cost_analysis undercounts scan bodies).
"""
from __future__ import annotations

import functools
import json
import pathlib

import jax

from repro.configs.base import ARCH_IDS, SHAPES, cell_applicable, get_config
from repro.launch.dryrun import ART_DIR
from repro.models import lm
from repro.roofline import analysis, analytic

MESH = {"data": 16, "model": 16}


@functools.lru_cache(maxsize=None)
def _param_counts(arch):
    cfg = get_config(arch)
    shapes = jax.eval_shape(functools.partial(lm.init, cfg=cfg),
                            jax.random.PRNGKey(0))
    n = analysis.count_params_from_shapes(shapes)
    return n, analysis.active_param_count(cfg, n)


def cell_roofline(arch, shape_name, serve_mode="cfmm"):
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape_name)
    if not ok:
        return {"skipped": True, "reason": why}
    n, n_active = _param_counts(arch)
    step = SHAPES[shape_name]["step"]
    mflops = analysis.model_flops_for(cfg, n, n_active, SHAPES[shape_name],
                                      step)
    roof = analytic.roofline_of(cfg, shape_name, MESH, n, n_active,
                                serve_mode, mflops)
    rec = roof.to_dict()
    rec["arch"], rec["shape"], rec["step"] = arch, shape_name, step
    # attach the HLO-derived terms from the dry-run artifact if present
    art = ART_DIR / "single" / f"{arch}__{shape_name}.json"
    if art.exists():
        d = json.loads(art.read_text())
        if "roofline" in d:
            rec["hlo"] = {k: d["roofline"][k] for k in
                          ("compute_s", "memory_s", "collective_s",
                           "dominant")}
            rec["compile_s"] = d.get("compile_s")
    return rec


def run(full=False, serve_mode="cfmm"):
    rows = []
    print(f" {'arch':22s} {'shape':12s} {'dom':11s} {'compute_s':>10s} "
          f"{'memory_s':>10s} {'coll_s':>10s} {'roofline%':>9s}")
    for arch in ARCH_IDS:
        for shape in SHAPES:
            rec = cell_roofline(arch, shape, serve_mode)
            if rec.get("skipped"):
                print(f" {arch:22s} {shape:12s} SKIP ({rec['reason'][:40]}...)")
                rows.append({"arch": arch, "shape": shape, **rec})
                continue
            print(f" {arch:22s} {shape:12s} {rec['dominant']:11s} "
                  f"{rec['compute_s']:10.2e} {rec['memory_s']:10.2e} "
                  f"{rec['collective_s']:10.2e} "
                  f"{100 * rec['roofline_fraction']:8.1f}%")
            rows.append(rec)
    # headline aggregates
    live = [r for r in rows if not r.get("skipped")]
    worst = min(live, key=lambda r: r["roofline_fraction"])
    coll = max(live, key=lambda r: r["collective_s"] /
               max(r["compute_s"] + r["memory_s"], 1e-12))
    print(f"\n worst roofline fraction: {worst['arch']}/{worst['shape']} "
          f"({100 * worst['roofline_fraction']:.1f}%)")
    print(f" most collective-bound:  {coll['arch']}/{coll['shape']}")
    return {"mesh": "16x16", "serve_mode": serve_mode, "rows": rows}

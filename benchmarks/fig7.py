"""Paper Fig 7 — throughput-balanced multi-chip ResNet50 partitioning."""
import json

from repro.core import partition
from repro.core.fpga_model import FIG7, GX280, GX550


def run(full=False):
    res = partition.fig7_projection()
    print(json.dumps(res, indent=1,
                     default=lambda o: round(o, 2) if isinstance(o, float)
                     else str(o)))
    best = res["model_best"]
    print(f" paper claim: {FIG7['im_s_per_chip_gx280']} im/s/chip GX280 "
          f"({FIG7['im_s_total']} im/s total, <= {FIG7['max_link_gbps']} Gbps)")
    print(f" model:       {best['im_s_per_chip']:.0f} im/s/chip GX280 at "
          f"{best['achieved_im_s']:.0f} im/s, {best['n_chips']} chips, "
          f"max link {best['max_link_gbps']:.1f} Gbps "
          f"(bottleneck: {best['bottleneck']})")
    ratio = best["im_s_per_chip"] / FIG7["im_s_per_chip_gx280"]
    print(f" model/claim ratio: {ratio:.2f} — the paper itself marks Fig 7 "
          f"as an unvalidated estimate; our corner-calibrated model is "
          f"{1 / max(ratio, 1e-9):.1f}x more conservative.")
    return res

"""Depthwise fused convolution — Pallas TPU kernel (DESIGN.md §12).

Implicit-GEMM degenerates at groups == C: each output channel reads ONE
input channel, so the per-tap (c_in, bn) matmul slab collapses to a
diagonal and the MXU would burn c_in x multiplies per useful MAC.  This
kernel keeps the same grid, strip tiling, and fused Collector epilogue as
kernels/conv_implicit.py but replaces the tap matmul with a VPU
elementwise tap-MAC:

    acc[m, c] += x[oh*s + dy, ow*s + dx, c] * w[dy*k + dx, c]

Weights arrive tap-major (k*k, C) int8 — stored that way at compile time
(nn.dwconv_param already initializes in this layout, so compilation does
zero shuffles) — and each grid cell holds a CHANNEL-TILED halo'd slab
(slab_h, Wp, bn): unlike the dense kernel, whose every output tile needs
all input channels, a depthwise output tile touches exactly its own bn
input channels, so the slab read shrinks with the channel grid axis.

Grid: (N, n_strips, C/bn).  Outputs match conv_implicit's contract —
strip-blocked f32 y plus the per-(image, strip, tile) amax (and the
optional zero-count pair) — so ops.conv2d_dw reuses the same unblocking
and requantization tail as ops.conv2d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv_implicit import collector_epilogue
from repro.kernels.tiling import strip_geometry


def dw_tap_macs(x, w_tap, k, stride, h_out, w_out):
    """Depthwise tap-MAC loop: one strided VMEM slice + VPU elementwise
    multiply-accumulate per tap, the k*k loop unrolled at trace time.

    x: (slab_h, Wp, bn) int8 slab; w_tap: (k*k, bn) int8 -> (m_out, bn)
    int32, m_out = h_out * w_out.
    """
    bn = x.shape[-1]
    m_out = h_out * w_out
    acc = jnp.zeros((m_out, bn), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            sl = jax.lax.slice(
                x, (dy, dx, 0),
                (dy + (h_out - 1) * stride + 1,
                 dx + (w_out - 1) * stride + 1, bn),
                (stride, stride, 1)).reshape(m_out, bn)
            acc += sl.astype(jnp.int32) * w_tap[dy * k + dx].astype(jnp.int32)
    return acc


def _kernel(*refs, k, stride, strip_h, h_out, w_out, ms_pad, relu,
            has_shortcut, profile_g):
    n_in = 5 if has_shortcut else 4
    ins, outs = refs[:n_in], refs[n_in:]
    if has_shortcut:
        x_ref, w_ref, s_ref, b_ref, sc_ref = ins
    else:
        x_ref, w_ref, s_ref, b_ref = ins
        sc_ref = None
    out_ref, amax_ref = outs[0], outs[1]
    zero_refs = (outs[2], outs[3]) if profile_g else None
    x = x_ref[0]                            # (slab_h, Wp, bn) int8, VMEM
    acc = dw_tap_macs(x, w_ref[...], k, stride, strip_h, w_out)
    valid = jnp.minimum(strip_h, h_out - pl.program_id(1) * strip_h) * w_out
    collector_epilogue(acc, s_ref, b_ref, sc_ref, out_ref, amax_ref,
                       m_out=strip_h * w_out, m_pad=ms_pad, relu=relu,
                       valid_rows=valid, zero_refs=zero_refs,
                       group_size=profile_g)


@functools.partial(jax.jit, static_argnames=(
    "k", "stride", "h_out", "w_out", "bn", "strip_h", "relu", "interpret",
    "profile_g"))
def conv2d_dw_pallas(x_pad: jax.Array, w_tap: jax.Array,
                     eff_scale: jax.Array, eff_bias: jax.Array,
                     shortcut: jax.Array | None = None, *,
                     k: int, stride: int, h_out: int, w_out: int,
                     bn: int = 128, strip_h: int | None = None,
                     relu: bool = True, interpret: bool = False,
                     profile_g: int | None = None):
    """Fused row-strip-tiled depthwise conv.

    x_pad:     (N, Hp, Wp, C) int8, SAME-padded and bottom-padded to the
               strip plan's x_rows (channels padded to the bn tile)
    w_tap:     (k*k, C) int8, tap-major (the compile-time storage layout)
    eff_scale: (N, C) f32 = s_x[row] * w_scale[channel] * bn_scale
    eff_bias:  (1, C) f32
    shortcut:  optional (N, n_strips*ms_pad, C) f32, strip-blocked
    Returns (y, amax) — strip-blocked f32 y (N, n_strips*ms_pad, C) and
    per-(image, strip, channel-tile) max|y| over valid rows — or
    (y, amax, zg, za) with ``profile_g`` (same contract as the dense
    implicit-GEMM kernel, shared unblocking in ops.conv2d_dw).
    """
    N, Hp, Wp, C = x_pad.shape
    KK, n_out = w_tap.shape
    assert KK == k * k and n_out == C and C % bn == 0, \
        ((KK, k), (n_out, C, bn))
    assert eff_scale.shape == (N, C), (eff_scale.shape, N, C)
    g = strip_geometry(k=k, stride=stride, h_out=h_out, w_out=w_out,
                       strip_h=strip_h if strip_h is not None else h_out)
    assert Hp >= g.x_rows and Wp >= (w_out - 1) * stride + k, \
        ((Hp, Wp), g.x_rows)
    n_j = C // bn
    kern = functools.partial(_kernel, k=k, stride=stride, strip_h=g.strip_h,
                             h_out=h_out, w_out=w_out, ms_pad=g.ms_pad,
                             relu=relu, has_shortcut=shortcut is not None,
                             profile_g=profile_g)
    in_specs = [
        # overlapping halo'd slabs, channel-tiled: a depthwise output tile
        # reads only its own bn input channels (Unblocked element offsets)
        pl.BlockSpec((1, g.slab_h, Wp, bn),
                     lambda n, s, j: (n, s * g.row_step, 0, j * bn),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((KK, bn), lambda n, s, j: (0, j)),
        # eff_scale: one dequant row PER IMAGE (per-row quant domains)
        pl.BlockSpec((1, bn), lambda n, s, j: (n, j)),
        pl.BlockSpec((1, bn), lambda n, s, j: (0, j)),
    ]
    args = [x_pad, w_tap, eff_scale, eff_bias]
    if shortcut is not None:
        assert shortcut.shape == (N, g.n_strips * g.ms_pad, C), \
            (shortcut.shape, g)
        in_specs.append(
            pl.BlockSpec((1, g.ms_pad, bn), lambda n, s, j: (n, s, j)))
        args.append(shortcut.astype(jnp.float32))
    out_specs = [pl.BlockSpec((1, g.ms_pad, bn), lambda n, s, j: (n, s, j)),
                 pl.BlockSpec((1, 1, 1), lambda n, s, j: (n, s, j))]
    out_shape = [jax.ShapeDtypeStruct((N, g.n_strips * g.ms_pad, C),
                                      jnp.float32),
                 jax.ShapeDtypeStruct((N, g.n_strips, n_j), jnp.float32)]
    if profile_g:
        assert bn % profile_g == 0, (bn, profile_g)
        gpb = bn // profile_g
        out_specs += [pl.BlockSpec((1, 1, 1, gpb),
                                   lambda n, s, j: (n, s, j, 0))] * 2
        out_shape += [jax.ShapeDtypeStruct((N, g.n_strips, n_j, gpb),
                                           jnp.float32)] * 2
    outs = pl.pallas_call(
        kern,
        grid=(N, g.n_strips, n_j),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return tuple(outs)

"""Flash attention — Pallas TPU kernel (prefill/train hot spot).

GQA-native streaming-softmax attention with the same schedule as the pure
JAX lowering in models/attention.py: grid over (batch*kv_head, q blocks,
kv blocks), kv innermost; running (m, l, o) state in VMEM scratch; causal
and sliding-window masking by absolute positions; query groups share one
K/V tile (no materialized repeat).

Block shapes default to (128 q x 128 kv) tiles at D <= 256: working set
q (G*bq*D) + k/v (bk*D*2) + o (G*bq*Dv) + p (G*bq*bk) ~ 0.6 MB in VMEM.
Causal pruning: kv blocks strictly above the diagonal are skipped by an
in-kernel predicate (the dominant-term win vs dense scores at long S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, n_kv: int, causal: bool, window,
            q_offset: int, scale: float):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos0 = q_i * bq + q_offset
    kpos0 = kv_i * bk
    # causal block pruning: skip blocks entirely above the diagonal or
    # entirely left of the sliding window
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, kpos0 <= qpos0 + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, kpos0 + bk - 1 > qpos0 - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                       # (G, bq, D)
        k = k_ref[0]                       # (bk, D)
        v = v_ref[0]                       # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bq, bk)
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask[None], s, NEG_INF)
        m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None], p, 0.0)
        alpha = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, bq, Dv)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _done():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[..., None]
                      ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, window=None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, KVH, G, Tq, D); k: (B, KVH, Tk, D); v: (B, KVH, Tk, Dv).

    Tq % bq == 0 and Tk % bk == 0 (kernels.ops pads); queries sit at the
    end of the KV sequence (offset = Tk - Tq).
    """
    B, KVH, G, Tq, D = q.shape
    Tk, Dv = k.shape[2], v.shape[-1]
    assert Tq % bq == 0 and Tk % bk == 0, ((Tq, Tk), (bq, bk))
    n_q, n_kv = Tq // bq, Tk // bk
    scale = 1.0 / (D ** 0.5)
    qr = q.reshape(B * KVH, G, Tq, D)
    kr = k.reshape(B * KVH, Tk, D)
    vr = v.reshape(B * KVH, Tk, Dv)
    kern = functools.partial(_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
                             window=window, q_offset=Tk - Tq, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B * KVH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, G, bq, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, bq, Dv), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, G, Tq, Dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KVH, G, Tq, Dv)

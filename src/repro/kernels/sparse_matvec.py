"""Bitmap-packed sparse matvec — Pallas TPU kernel (decode hot path).

The paper's zero-overhead unstructured sparsity, converted to the thing a
TPU can actually exploit: **weight-read bandwidth**.  Decode (batch x 1
token) is weight-bound; at 80% sparsity the bitmap format reads
(1-s)*8 + 1 = 2.6 bits/param instead of 16 (bf16) — up to ~6x effective
bandwidth, on top of int8's 2x compute rate.

Format (core.compiled_linear.bitmap_pack):
  bitmap (K/8, N) uint8 — little-endian validity bits down the K axis
  values (keep_k, N) int8 — nonzero codes in ascending-row order per column

Kernel: grid over N tiles; K is processed in VMEM-resident chunks with a
running per-column nonzero count carried across chunks (the expand tile
lives in kernels/bitmap.py, shared with the bitmap-native conv kernel).
The expansion lives entirely in VMEM — HBM only ever sees packed bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitmap import expand_bitmap_tile


def _kernel(x_ref, bitmap_ref, values_ref, scale_ref, out_ref, acc_ref,
            *, k_chunk: int, n_chunks: int, keep_k: int):
    M = x_ref.shape[0]
    bn = out_ref.shape[1]

    def body(c, carry):
        base = carry  # (1, bn) int32: nonzeros consumed per column so far
        rows8 = k_chunk // 8
        bm8 = bitmap_ref[pl.ds(c * rows8, rows8), :]            # (rows8, bn)
        w_chunk, base = expand_bitmap_tile(bm8, values_ref[...], base,
                                           keep_k)              # (kc, bn)
        x_chunk = x_ref[:, pl.ds(c * k_chunk, k_chunk)]         # (M, kc)
        acc_ref[...] += jax.lax.dot_general(
            x_chunk, w_chunk, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return base

    acc_ref[...] = jnp.zeros_like(acc_ref)
    jax.lax.fori_loop(0, n_chunks, body,
                      jnp.zeros((1, bn), jnp.int32), unroll=False)
    out_ref[...] = (acc_ref[...].astype(jnp.float32)
                    * scale_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "k_chunk", "interpret"))
def sparse_matvec_pallas(x_q: jax.Array, bitmap: jax.Array,
                         values: jax.Array, scale: jax.Array,
                         bn: int = 128, k_chunk: int = 1024,
                         interpret: bool = False) -> jax.Array:
    """x_q (M, K) int8 @ bitmap-packed (K, N) -> f32 (M, N), w-scale fused.

    M is small (decode batch per shard); K % k_chunk == 0, N % bn == 0
    (caller pads).  VMEM/N-tile: values keep_k*bn + bitmap K/8*bn + chunk
    2*k_chunk*bn + x M*K — ~1.1 MB at K=8192, keep_k=K/5, bn=128.
    """
    M, K = x_q.shape
    Kb, N = bitmap.shape
    keep_k = values.shape[0]
    assert Kb * 8 == K and K % k_chunk == 0 and N % bn == 0, (
        (M, K, N), (Kb, keep_k), (bn, k_chunk))
    out = pl.pallas_call(
        functools.partial(_kernel, k_chunk=k_chunk,
                          n_chunks=K // k_chunk, keep_k=keep_k),
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((M, K), lambda j: (0, 0)),
            pl.BlockSpec((Kb, bn), lambda j: (0, j)),
            pl.BlockSpec((keep_k, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((M, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((M, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, bitmap, values, scale)
    return out

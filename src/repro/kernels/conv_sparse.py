"""Bitmap-native implicit-GEMM sparse convolution — Pallas TPU kernel.

The paper's headline numbers come from *sparse constant parameters*: at
s=0.8 the bitmap format stores (1-s)*8 + 1 = 2.6 bits/param instead of
the 8 bits of dense int8 codes.  `conv_implicit.py` already keeps the
im2col patch tensor out of HBM; this kernel carries the packed-weight win
into the same launch — HBM only ever sees `(bitmap, values)` bytes, and
the dense tap slabs exist solely in VMEM.

Format (core.compiled_linear.compile_params, conv leaves, sparse_cfmm):
  weights are *spatial-major* (k*k*c_in, c_out) — row = tap*c_in + c —
  with K padded up to a multiple of 8 by all-zero masked tap rows, then
  bitmap-packed column-wise:
    bitmap (K_pad/8, c_out) uint8, values (keep_k, c_out) int8.

Kernel: grid (N, n_strips, c_out/bn), identical to conv_implicit — the
input streams as halo'd row strips (kernels/tiling.py) while the packed
weight slab is re-read per cell and expands via the shared
`kernels.bitmap.expand_bitmap_tile`:

* c_in % 8 == 0 — expand *per k-tap tile*, fused with the MAC: each tap's
  (c_in, bn) slab is expanded and immediately fed to the MXU, carrying the
  running nonzero count tap to tap; the full dense weight never exists.
* otherwise (e.g. the c_in=3 stem) — byte rows straddle tap boundaries,
  so the whole (K_pad, bn) slab expands in one tile, then the tap loop
  slices it; still VMEM-only.

The MAC loop and the Collector epilogue (dequant * folded-BN scale, bias,
shortcut, ReLU, on-chip per-strip amax for the quantization-domain pass)
are *shared code* with `conv_implicit.py` (`conv_tap_macs` /
`collector_epilogue`) — only the tap-weight sourcing differs — so sparse
and dense conv outputs are bit-identical for identical (expanded) codes
by construction, tiled or not.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitmap import expand_bitmap_tile
from repro.kernels.conv_implicit import collector_epilogue, conv_tap_macs
from repro.kernels.tiling import strip_geometry


def _kernel(*refs, k, stride, strip_h, h_out, w_out, ms_pad, relu,
            has_shortcut, c_in, keep_k, profile_g):
    n_in = 6 if has_shortcut else 5
    ins, outs = refs[:n_in], refs[n_in:]
    if has_shortcut:
        x_ref, bm_ref, val_ref, s_ref, b_ref, sc_ref = ins
    else:
        x_ref, bm_ref, val_ref, s_ref, b_ref = ins
        sc_ref = None
    out_ref, amax_ref = outs[0], outs[1]
    zero_refs = (outs[2], outs[3]) if profile_g else None
    x = x_ref[0]                                # (slab_h, Wp, C) int8, VMEM
    C = x.shape[-1]
    bn = out_ref.shape[2]
    vals = val_ref[...]
    # the MAC loop and Collector are conv_implicit's own (shared code, so
    # sparse == dense bit-identity holds by construction); only the tap
    # weight sourcing differs — packed bytes expand on the fly in VMEM
    if c_in % 8 == 0:                              # tap rows byte-aligned:
        def tap_weights(tap, base):                # expand fused per tap,
            bm8 = bm_ref[tap * C // 8:(tap + 1) * C // 8, :]
            return expand_bitmap_tile(bm8, vals, base, keep_k)
        carry = jnp.zeros((1, bn), jnp.int32)      # running nonzero count
    else:                                          # taps straddle bytes
        w_dense, _ = expand_bitmap_tile(           # (stem): one-shot slab
            bm_ref[...], vals, jnp.zeros((1, bn), jnp.int32), keep_k)

        def tap_weights(tap, carry):
            return jax.lax.slice(w_dense, (tap * C, 0),
                                 ((tap + 1) * C, bn)), carry
        carry = None
    acc = conv_tap_macs(x, k, stride, strip_h, w_out, bn, tap_weights, carry)
    valid = jnp.minimum(strip_h, h_out - pl.program_id(1) * strip_h) * w_out
    collector_epilogue(acc, s_ref, b_ref, sc_ref, out_ref, amax_ref,
                       m_out=strip_h * w_out, m_pad=ms_pad, relu=relu,
                       valid_rows=valid, zero_refs=zero_refs,
                       group_size=profile_g)


@functools.partial(jax.jit, static_argnames=(
    "k", "stride", "h_out", "w_out", "bn", "strip_h", "relu", "interpret",
    "profile_g"))
def conv2d_sparse_pallas(x_pad: jax.Array, bitmap: jax.Array,
                         values: jax.Array, eff_scale: jax.Array,
                         eff_bias: jax.Array,
                         shortcut: jax.Array | None = None, *,
                         k: int, stride: int, h_out: int, w_out: int,
                         bn: int = 128, strip_h: int | None = None,
                         relu: bool = True, interpret: bool = False,
                         profile_g: int | None = None):
    """Fused bitmap-native row-strip-tiled implicit-GEMM sparse conv.

    x_pad:     (N, Hp, Wp, C) int8, SAME-padded (ref.pad_same_nhwc) and
               bottom-padded with zero rows to the strip plan's x_rows
    bitmap:    (K_pad/8, n_out) uint8, spatial-major taps, K_pad =
               k*k*C rounded up to a multiple of 8 (zero-masked tail)
    values:    (keep_k, n_out) int8 nonzero codes, ascending-row order
    eff_scale: (N, n_out) f32 = s_x[row] * w_scale * bn_scale, one row
               per image (per-row quantization domains; a per-tensor
               domain broadcasts one row); eff_bias (1, n_out) f32
    shortcut:  optional (N, n_strips*ms_pad, n_out) f32, strip-blocked
    strip_h:   output rows per strip; None = one whole-image strip
    profile_g: opt-in sparsity profiling group size (see
               conv2d_implicit_pallas — identical outputs/semantics)
    Returns (y, amax) exactly as conv2d_implicit_pallas
    ((y, amax, zg, za) with ``profile_g``).
    """
    N, Hp, Wp, C = x_pad.shape
    Kb8, n_out = bitmap.shape
    keep_k = values.shape[0]
    assert Kb8 * 8 == -(-k * k * C // 8) * 8, (Kb8, k, C)
    assert n_out % bn == 0 and values.shape[1] == n_out, (n_out, bn)
    assert eff_scale.shape == (N, n_out), (eff_scale.shape, N, n_out)
    g = strip_geometry(k=k, stride=stride, h_out=h_out, w_out=w_out,
                       strip_h=strip_h if strip_h is not None else h_out)
    assert Hp >= g.x_rows and Wp >= (w_out - 1) * stride + k, \
        ((Hp, Wp), g.x_rows)
    n_j = n_out // bn
    kern = functools.partial(_kernel, k=k, stride=stride, strip_h=g.strip_h,
                             h_out=h_out, w_out=w_out, ms_pad=g.ms_pad,
                             relu=relu, has_shortcut=shortcut is not None,
                             c_in=C, keep_k=keep_k, profile_g=profile_g)
    in_specs = [
        # overlapping halo'd slabs: Unblocked = element-offset indexing
        pl.BlockSpec((1, g.slab_h, Wp, C),
                     lambda n, s, j: (n, s * g.row_step, 0, 0),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((Kb8, bn), lambda n, s, j: (0, j)),
        pl.BlockSpec((keep_k, bn), lambda n, s, j: (0, j)),
        # eff_scale: one dequant row PER IMAGE (per-row quant domains)
        pl.BlockSpec((1, bn), lambda n, s, j: (n, j)),
        pl.BlockSpec((1, bn), lambda n, s, j: (0, j)),
    ]
    args = [x_pad, bitmap, values, eff_scale, eff_bias]
    if shortcut is not None:
        assert shortcut.shape == (N, g.n_strips * g.ms_pad, n_out), \
            (shortcut.shape, g)
        in_specs.append(
            pl.BlockSpec((1, g.ms_pad, bn), lambda n, s, j: (n, s, j)))
        args.append(shortcut.astype(jnp.float32))
    out_specs = [pl.BlockSpec((1, g.ms_pad, bn), lambda n, s, j: (n, s, j)),
                 pl.BlockSpec((1, 1, 1), lambda n, s, j: (n, s, j))]
    out_shape = [jax.ShapeDtypeStruct((N, g.n_strips * g.ms_pad, n_out),
                                      jnp.float32),
                 jax.ShapeDtypeStruct((N, g.n_strips, n_j), jnp.float32)]
    if profile_g:
        assert bn % profile_g == 0, (bn, profile_g)
        gpb = bn // profile_g
        out_specs += [pl.BlockSpec((1, 1, 1, gpb),
                                   lambda n, s, j: (n, s, j, 0))] * 2
        out_shape += [jax.ShapeDtypeStruct((N, g.n_strips, n_j, gpb),
                                           jnp.float32)] * 2
    outs = pl.pallas_call(
        kern,
        grid=(N, g.n_strips, n_j),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return tuple(outs)

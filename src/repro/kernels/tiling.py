"""Row-strip planner for the conv kernels (DESIGN.md §3, §6).

The paper's persistent design streams feature maps through fixed on-chip
buffers; the Pallas analogue bounds the per-grid-cell VMEM working set by
tiling the conv over *row strips with a k−1-row halo* instead of parking
one whole padded image in VMEM.  The grid grows from `(N, c_out/bn)` to
`(N, n_strips, c_out/bn)`, throughput becomes independent of image
height, and each cell holds only

    x slab   (slab_h, Wp, c_in) int8,  slab_h = (strip_h−1)·stride + k
    weights  one c_out tile of constant codes (dense or bitmap-packed)
    acc/y    (strip_h·w_out, bn) int32 / f32 (+ shortcut f32 if present)

Strip s reads padded input rows `[s·strip_h·stride, s·strip_h·stride +
slab_h)` — consecutive strips overlap by the `k − stride` halo rows — and
owns output rows `[s·strip_h, (s+1)·strip_h)`.  Because every output row
depends only on input rows inside its strip's slab, the tiled conv is
bit-identical to the untiled one by construction; the last strip may run
past `h_out` (the caller pads the input with zero rows, exact for int8)
and its surplus rows are masked out of the on-chip amax and sliced off
after the launch.

``plan_strips`` picks the largest ``strip_h`` whose working set fits a
VMEM budget; 7×7-map layers (conv5_x) degenerate to a single strip, i.e.
exactly the pre-tiling kernel.
"""
from __future__ import annotations

import dataclasses

# Per-grid-cell working-set budget.  VMEM is ~16 MB/core; 1 MiB per cell
# leaves room for double-buffered input/weight streams and keeps several
# (n, strip, c_out-tile) cells in flight.  The 224×224 k=7 stem's
# whole-image working set (dominated by the 112×112-row accumulator)
# shrinks well over 4× under it (tracked in BENCH_conv.json).
DEFAULT_VMEM_BUDGET = 1 << 20


@dataclasses.dataclass(frozen=True)
class StripPlan:
    """Static row-strip geometry (plus working-set accounting) for one
    conv launch."""

    strip_h: int     # output rows per strip
    n_strips: int    # ceil(h_out / strip_h)
    slab_h: int      # input rows resident per cell = (strip_h-1)*stride + k
    row_step: int    # input-row stride between strips = strip_h * stride
    ms: int          # output elements per strip = strip_h * w_out
    ms_pad: int      # ms rounded up to the f32 sublane multiple (8)
    x_rows: int      # padded-input rows the kernel reads overall
    x_bytes: int = 0     # int8 activation slab bytes per cell
    cell_bytes: int = 0  # slab + weight tile + acc/y (+shortcut) bytes


def strip_geometry(*, k: int, stride: int, h_out: int, w_out: int,
                   strip_h: int) -> StripPlan:
    """Pure strip geometry for a given strip_h (no budget accounting) —
    what the Pallas kernels and the strip-looped jnp lowering share."""
    strip_h = max(1, min(strip_h, h_out))
    n_strips = -(-h_out // strip_h)
    slab_h = (strip_h - 1) * stride + k
    ms = strip_h * w_out
    return StripPlan(
        strip_h=strip_h, n_strips=n_strips, slab_h=slab_h,
        row_step=strip_h * stride, ms=ms, ms_pad=-(-ms // 8) * 8,
        x_rows=(n_strips - 1) * strip_h * stride + slab_h)


def plan_strips(*, k: int, stride: int, h_out: int, w_out: int, wp: int,
                c_in: int, bn: int, weight_bytes: int,
                has_shortcut: bool = False,
                budget: int = DEFAULT_VMEM_BUDGET,
                strip_h: int | None = None) -> StripPlan:
    """Pick output-rows-per-strip from the VMEM budget.

    Cell working set = `slab_h·Wp·c_in` (int8 x slab) + ``weight_bytes``
    (one c_out-tile of constant codes, packed or dense) + `ms_pad·bn·4`
    for each of the int32 accumulator, the f32 y tile, and — when present
    — the f32 shortcut tile.  Returns the largest ``strip_h ≤ h_out``
    that fits, degenerating to one strip when the whole image fits (7×7
    maps) and to single-row strips when even those exceed the budget.
    ``strip_h`` overrides the search (tests / benchmarks force awkward
    strip boundaries).
    """
    wp_c = wp * c_in

    def plan_of(sh: int) -> StripPlan:
        g = strip_geometry(k=k, stride=stride, h_out=h_out, w_out=w_out,
                           strip_h=sh)
        acc_y = g.ms_pad * bn * 4 * (3 if has_shortcut else 2)
        return dataclasses.replace(
            g, x_bytes=g.slab_h * wp_c,
            cell_bytes=g.slab_h * wp_c + weight_bytes + acc_y)

    if strip_h is not None:
        return plan_of(strip_h)
    best = plan_of(1)
    for sh in range(2, h_out + 1):
        cand = plan_of(sh)
        if cand.cell_bytes > budget:
            break
        best = cand
    return best

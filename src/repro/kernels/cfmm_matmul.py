"""CFMM quantized matmul — Pallas TPU kernel.

TPU-native realization of the paper's CFMM dataflow (DESIGN.md SS2): the
packed constant INT7 codes stream HBM->VMEM tile by tile, are "decoded"
in VMEM (for int8 codes the decode is the identity — the 32-odd-product
structure lives in the packing; see sparse_matvec for the bitmap format),
and hit the MXU as int8 x int8 -> int32 with the per-output-channel
dequant scale fused into the epilogue (the paper's Collector, SS II-D.4).

Grid: (M/bm, N/bn, K/bk), K innermost, int32 accumulator in VMEM scratch.
Default blocks are MXU-aligned (128, 128) with bk=512, keeping the working
set (bm*bk + bk*bn + 4*bm*bn + 4*bn) well under VMEM (~0.2 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, sw_ref, out_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # VMEM decode of the packed constant parameters is the identity for
    # int8 codes; the MXU consumes them directly at 2x bf16 peak.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        # fused Collector epilogue: per-output-channel dequant scale
        out_ref[...] = (acc_ref[...].astype(jnp.float32)
                        * sw_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def cfmm_matmul_pallas(x_q: jax.Array, codes: jax.Array, scale: jax.Array,
                       bm: int = 128, bn: int = 128, bk: int = 512,
                       interpret: bool = False) -> jax.Array:
    """x_q (M, K) int8 @ codes (K, N) int8 -> f32 (M, N), w-scale fused.

    scale: (1, N) f32 per-output-channel weight scale.  The caller
    (kernels.ops) pads M/N/K to block multiples and applies the scalar
    activation scale.
    """
    M, K = x_q.shape
    K2, N = codes.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0, (
        (M, K, N), (bm, bn, bk))
    n_k = K // bk
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, codes, scale)
    return out

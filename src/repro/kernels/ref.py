"""Pure-jnp oracles for every Pallas kernel (and the non-TPU lowering).

These are the mathematically exact references the kernels must match
bit-for-bit (integer paths) or to fp tolerance (scaled outputs).  They are
also what the multi-pod dry-run lowers on the CPU backend — same sharding,
same dtypes, so the compiled HLO is representative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(x_q: jax.Array, codes: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 (exact)."""
    return jax.lax.dot_general(
        x_q, codes, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def cfmm_matmul_ref(x_q: jax.Array, codes: jax.Array,
                    scale: jax.Array) -> jax.Array:
    return int8_matmul_ref(x_q, codes).astype(jnp.float32) * scale


def bitmap_expand_ref(bitmap: jax.Array, values: jax.Array) -> jax.Array:
    """(K/8, N) uint8 bitmap + (keep_k, N) int8 -> dense int8 codes (K, N)."""
    from repro.core.compiled_linear import bitmap_unpack
    return bitmap_unpack(bitmap, values)


def sparse_matvec_ref(x_q: jax.Array, bitmap: jax.Array,
                      values: jax.Array) -> jax.Array:
    """x_q (M, K) int8 @ bitmap-packed codes -> int32 (M, N) (exact)."""
    return int8_matmul_ref(x_q, bitmap_expand_ref(bitmap, values))


def block_sparse_matmul_ref(x: jax.Array, w_blocks: jax.Array,
                            block_kn, mask) -> jax.Array:
    """x (M, K) @ block-sparse W -> (M, N).

    w_blocks: (n_active, bk, bn) dense storage of active blocks;
    mask: (K//bk, N//bn) bool numpy, row-major ordering of active blocks.
    """
    import numpy as np
    bk, bn = block_kn
    Kb, Nb = mask.shape
    K, N = Kb * bk, Nb * bn
    w = jnp.zeros((K, N), w_blocks.dtype)
    idx = 0
    for kb in range(Kb):
        for nb in range(Nb):
            if mask[kb, nb]:
                w = w.at[kb * bk:(kb + 1) * bk, nb * bn:(nb + 1) * bn].set(
                    w_blocks[idx])
                idx += 1
    assert idx == w_blocks.shape[0]
    if x.dtype == jnp.int8:
        return int8_matmul_ref(x, w)
    return x @ w


def flash_attention_ref(q, k, v, causal=True, window=None):
    """Naive softmax attention oracle for the chunked/flash paths.

    q,k,v: (B, H, T, D) (k/v may have fewer heads: GQA handled by caller).
    """
    T, S = q.shape[-2], k.shape[-2]
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(q.shape[-1])
    pos_q = jnp.arange(T)[:, None] + (S - T)
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, v)

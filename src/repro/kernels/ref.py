"""Pure-jnp oracles for every Pallas kernel (and the non-TPU lowering).

These are the mathematically exact references the kernels must match
bit-for-bit (integer paths) or to fp tolerance (scaled outputs).  They are
also what the multi-pod dry-run lowers on the CPU backend — same sharding,
same dtypes, so the compiled HLO is representative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(x_q: jax.Array, codes: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 (exact)."""
    return jax.lax.dot_general(
        x_q, codes, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def cfmm_matmul_ref(x_q: jax.Array, codes: jax.Array,
                    scale: jax.Array) -> jax.Array:
    return int8_matmul_ref(x_q, codes).astype(jnp.float32) * scale


def bitmap_expand_ref(bitmap: jax.Array, values: jax.Array) -> jax.Array:
    """(K/8, N) uint8 bitmap + (keep_k, N) int8 -> dense int8 codes (K, N)."""
    from repro.core.compiled_linear import bitmap_unpack
    return bitmap_unpack(bitmap, values)


def sparse_matvec_ref(x_q: jax.Array, bitmap: jax.Array,
                      values: jax.Array) -> jax.Array:
    """x_q (M, K) int8 @ bitmap-packed codes -> int32 (M, N) (exact)."""
    return int8_matmul_ref(x_q, bitmap_expand_ref(bitmap, values))


def block_sparse_matmul_ref(x: jax.Array, w_blocks: jax.Array,
                            block_kn, mask) -> jax.Array:
    """x (M, K) @ block-sparse W -> (M, N).

    w_blocks: (n_active, bk, bn) dense storage of active blocks;
    mask: (K//bk, N//bn) bool numpy, row-major ordering of active blocks.
    """
    import numpy as np
    bk, bn = block_kn
    Kb, Nb = mask.shape
    K, N = Kb * bk, Nb * bn
    w = jnp.zeros((K, N), w_blocks.dtype)
    idx = 0
    for kb in range(Kb):
        for nb in range(Nb):
            if mask[kb, nb]:
                w = w.at[kb * bk:(kb + 1) * bk, nb * bn:(nb + 1) * bn].set(
                    w_blocks[idx])
                idx += 1
    assert idx == w_blocks.shape[0]
    if x.dtype == jnp.int8:
        return int8_matmul_ref(x, w)
    return x @ w


# ---------------------------------------------------------------------------
# Convolution (implicit-GEMM oracle + the materializing im2col baseline)
# ---------------------------------------------------------------------------

def to_spatial_major(codes: jax.Array, k: int, c_in: int) -> jax.Array:
    """Channel-major patch codes (c_in*k*k, n) -> spatial-major tap order
    (k*k*c_in, n), row = tap*c_in + c — the layout the conv kernels'
    tap loop consumes as contiguous (c_in, bn) slabs.

    The ONLY conv weight-layout shuffle in the codebase: `compile_params`
    runs it once at compile time for every dense conv leaf (as the bitmap
    packer already did), so `ops.conv2d` pays zero per-call permutes on
    the serving path (spy-tested in tests/test_conv.py).
    """
    n = codes.shape[-1]
    return codes.reshape(c_in, k, k, n).transpose(1, 2, 0, 3).reshape(
        k * k * c_in, n)


def from_spatial_major(codes_sp: jax.Array, k: int, c_in: int) -> jax.Array:
    """Inverse of ``to_spatial_major`` — oracle/debug seam only
    (`compiled_linear.packed_codes`), never on the serving hot path."""
    n = codes_sp.shape[-1]
    return codes_sp.reshape(k, k, c_in, n).transpose(2, 0, 1, 3).reshape(
        k * k * c_in, n)


def _w_sp4(codes: jax.Array, k: int, c_in: int, layout: str) -> jax.Array:
    """(k, k, c_in, n) tap-indexed weight view of flat conv codes.

    layout="spatial" (the compiled storage layout) is a pure reshape;
    layout="channel" (raw quantized codes in im2col patch order) pays the
    one permute through ``to_spatial_major``.
    """
    n = codes.shape[-1]
    if layout == "channel":
        codes = to_spatial_major(codes, k, c_in)
    else:
        assert layout == "spatial", layout
    return codes.reshape(k, k, c_in, n)


def same_pads(size: int, k: int, stride: int):
    """SAME-padding (lo, hi) and output size along one spatial dim."""
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return total // 2, total - total // 2, out


def pad_same_nhwc(x: jax.Array, k: int, stride: int):
    """Zero-pad (N,H,W,C) for SAME conv -> (padded, h_out, w_out).

    Zero padding is exact for symmetric int8 codes (zero point is 0).
    """
    _, H, W, _ = x.shape
    lo_h, hi_h, h_out = same_pads(H, k, stride)
    lo_w, hi_w, w_out = same_pads(W, k, stride)
    if lo_h or hi_h or lo_w or hi_w:
        x = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    return x, h_out, w_out


def _shift_slice(xp: jax.Array, dy: int, dx: int, h_out: int, w_out: int,
                 stride: int) -> jax.Array:
    """The (dy, dx) tap of the receptive field, strided to output positions."""
    return jax.lax.slice(
        xp, (0, dy, dx, 0),
        (xp.shape[0], dy + (h_out - 1) * stride + 1,
         dx + (w_out - 1) * stride + 1, xp.shape[3]),
        (1, stride, stride, 1))


def im2col_ref(x: jax.Array, k: int, stride: int) -> jax.Array:
    """Materialized SAME im2col patches, (N, h_out, w_out, C*k*k).

    Feature ordering is channel-major (c*k*k + ky*k + kx) — bit-identical
    to ``lax.conv_general_dilated_patches`` with NHWC dimension numbers,
    so flat (c_in*k*k, c_out) weights mean the same thing on both paths.
    This is the HBM-materializing baseline the implicit-GEMM kernel beats.
    """
    xp, h_out, w_out = pad_same_nhwc(x, k, stride)
    taps = [_shift_slice(xp, dy, dx, h_out, w_out, stride)
            for dy in range(k) for dx in range(k)]
    p = jnp.stack(taps, axis=-1)                    # (N, ho, wo, C, k*k)
    N, _, _, C = x.shape
    return p.reshape(N, h_out, w_out, C * k * k)


def _conv_taps_spatial(xp: jax.Array, w_sp: jax.Array, k: int, stride: int,
                       h_out: int, w_out: int) -> jax.Array:
    """Tap-loop int8 conv on a padded image with spatial-major weights.

    xp: (N, Hp, Wp, C) int8; w_sp: (k, k, C, n_out) int8 -> int32 NHWC.
    """
    N = xp.shape[0]
    n_out = w_sp.shape[-1]
    acc = jnp.zeros((N, h_out, w_out, n_out), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            sl = _shift_slice(xp, dy, dx, h_out, w_out, stride)
            acc = acc + jax.lax.dot_general(
                sl, w_sp[dy, dx], dimension_numbers=(((3,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
    return acc


def conv2d_int8_ref(x_q: jax.Array, codes: jax.Array, k: int,
                    stride: int, layout: str = "channel") -> jax.Array:
    """int8 NHWC conv -> int32 (exact): shift-slice matmuls, no im2col.

    codes: (c_in*k*k, c_out) int8 — patch (channel-major) order by
    default, or the compiled spatial-major tap order with
    layout="spatial" (a free reshape, no permute).
    """
    N, _, _, C = x_q.shape
    xp, h_out, w_out = pad_same_nhwc(x_q, k, stride)
    w_sp = _w_sp4(codes, k, C, layout)
    return _conv_taps_spatial(xp, w_sp, k, stride, h_out, w_out)


def conv2d_sparse_int8_ref(x_q: jax.Array, bitmap: jax.Array,
                           values: jax.Array, k: int,
                           stride: int) -> jax.Array:
    """Bitmap-native int8 conv oracle -> int32 (exact).

    bitmap/values: the packed *spatial-major* conv weight layout
    (kernels/conv_sparse.py) — rows tap*c_in + c, K padded to %8 with
    zero-masked tail rows.  The expansion runs through the same
    ``expand_bitmap_tile`` the Pallas kernels use (never through
    ``bitmap_unpack`` — this is the jnp lowering of the serving hot path,
    packed bytes in, VMEM-analogue expansion inside).
    """
    from repro.kernels.bitmap import expand_bitmap_tile
    N, _, _, C = x_q.shape
    n_out = bitmap.shape[1]
    kk = C * k * k
    w_dense, _ = expand_bitmap_tile(
        bitmap, values, jnp.zeros((1, n_out), jnp.int32), values.shape[0])
    w_sp = w_dense[:kk].reshape(k, k, C, n_out)
    xp, h_out, w_out = pad_same_nhwc(x_q, k, stride)
    return _conv_taps_spatial(xp, w_sp, k, stride, h_out, w_out)


def conv2d_collector_ref(x_q: jax.Array, codes: jax.Array, k: int,
                         stride: int, eff_scale: jax.Array,
                         eff_bias: jax.Array, shortcut=None,
                         relu: bool = True,
                         layout: str = "channel") -> jax.Array:
    """Fused conv + Collector oracle: dequant/BN scale, bias, shortcut, ReLU.

    eff_scale = s_x * w_scale * bn_scale and eff_bias = bias, both
    broadcastable against the NHWC accumulator — ``(c_out,)`` for a
    per-tensor quantization domain, ``(N, 1, 1, c_out)`` for per-row
    domains (one independent dequant row per image, DESIGN.md §9).
    """
    acc = conv2d_int8_ref(x_q, codes, k, stride, layout)
    return _collector(acc, eff_scale, eff_bias, shortcut, relu)


def conv2d_collector_strips_ref(x_q: jax.Array, codes, k: int, stride: int,
                                strip_h: int, eff_scale: jax.Array,
                                eff_bias: jax.Array, shortcut=None,
                                relu: bool = True,
                                layout: str = "spatial") -> jax.Array:
    """Row-strip-tiled jnp lowering of the fused conv (dense codes or the
    packed ``(bitmap, values)`` pair): loops the exact halo'd slabs the
    Pallas grid iterates (kernels/tiling.py), so the strip decomposition
    itself is testable in pure jnp — bit-identical to the untiled oracle
    by construction, since each output row sees the same input rows and
    the same per-tap MAC order.
    """
    from repro.kernels.tiling import strip_geometry
    N, _, _, C = x_q.shape
    if isinstance(codes, (tuple, list)):           # bitmap-packed weights
        from repro.kernels.bitmap import expand_bitmap_tile
        bitmap, values = codes
        n_out = bitmap.shape[1]
        dense, _ = expand_bitmap_tile(
            bitmap, values, jnp.zeros((1, n_out), jnp.int32),
            values.shape[0])
        w_sp = dense[:C * k * k].reshape(k, k, C, n_out)
    else:
        w_sp = _w_sp4(codes, k, C, layout)
    xp, h_out, w_out = pad_same_nhwc(x_q, k, stride)
    g = strip_geometry(k=k, stride=stride, h_out=h_out, w_out=w_out,
                       strip_h=strip_h)
    if xp.shape[1] < g.x_rows:                     # zero rows: exact int8
        xp = jnp.pad(xp, ((0, 0), (0, g.x_rows - xp.shape[1]),
                          (0, 0), (0, 0)))
    strips = []
    for s in range(g.n_strips):
        rows = min(g.strip_h, h_out - s * g.strip_h)
        slab = jax.lax.slice_in_dim(xp, s * g.row_step,
                                    s * g.row_step + g.slab_h, axis=1)
        acc = _conv_taps_spatial(slab, w_sp, k, stride, rows, w_out)
        sc = (None if shortcut is None
              else shortcut[:, s * g.strip_h:s * g.strip_h + rows])
        strips.append(_collector(acc, eff_scale, eff_bias, sc, relu))
    return jnp.concatenate(strips, axis=1)


def _dw_taps(xp: jax.Array, w_tap: jax.Array, k: int, stride: int,
             h_out: int, w_out: int) -> jax.Array:
    """Tap-loop depthwise int8 conv on a padded slab -> int32 NHWC.

    xp: (N, Hp, Wp, C) int8; w_tap: (k*k, C) int8 tap-major — each tap
    contributes an elementwise (per-channel) MAC instead of the dense
    conv's cross-channel matmul, which is exactly why implicit-GEMM
    degenerates at groups == C and depthwise gets its own kernel.
    """
    C = w_tap.shape[-1]
    acc = jnp.zeros((xp.shape[0], h_out, w_out, C), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            sl = _shift_slice(xp, dy, dx, h_out, w_out, stride)
            acc = acc + (sl.astype(jnp.int32)
                         * w_tap[dy * k + dx].astype(jnp.int32))
    return acc


def conv2d_dw_int8_ref(x_q: jax.Array, w_tap: jax.Array, k: int,
                       stride: int) -> jax.Array:
    """Depthwise int8 NHWC SAME conv -> int32 (exact)."""
    assert x_q.shape[-1] == w_tap.shape[-1], (x_q.shape, w_tap.shape)
    xp, h_out, w_out = pad_same_nhwc(x_q, k, stride)
    return _dw_taps(xp, w_tap, k, stride, h_out, w_out)


def conv2d_dw_collector_ref(x_q: jax.Array, w_tap: jax.Array, k: int,
                            stride: int, eff_scale: jax.Array,
                            eff_bias: jax.Array, shortcut=None,
                            relu: bool = True) -> jax.Array:
    """Fused depthwise conv + Collector oracle (same epilogue maths as the
    dense conv — shared ``_collector``, so the two kernel families are
    bit-identical in their Non-Kernel stage by construction)."""
    acc = conv2d_dw_int8_ref(x_q, w_tap, k, stride)
    return _collector(acc, eff_scale, eff_bias, shortcut, relu)


def conv2d_dw_collector_strips_ref(x_q: jax.Array, w_tap: jax.Array,
                                   k: int, stride: int, strip_h: int,
                                   eff_scale: jax.Array,
                                   eff_bias: jax.Array, shortcut=None,
                                   relu: bool = True) -> jax.Array:
    """Row-strip-tiled jnp lowering of the fused depthwise conv: loops the
    exact halo'd slabs the Pallas grid iterates — bit-identical to the
    untiled oracle by construction (same input rows, same tap order)."""
    from repro.kernels.tiling import strip_geometry
    xp, h_out, w_out = pad_same_nhwc(x_q, k, stride)
    g = strip_geometry(k=k, stride=stride, h_out=h_out, w_out=w_out,
                       strip_h=strip_h)
    if xp.shape[1] < g.x_rows:                     # zero rows: exact int8
        xp = jnp.pad(xp, ((0, 0), (0, g.x_rows - xp.shape[1]),
                          (0, 0), (0, 0)))
    strips = []
    for s in range(g.n_strips):
        rows = min(g.strip_h, h_out - s * g.strip_h)
        slab = jax.lax.slice_in_dim(xp, s * g.row_step,
                                    s * g.row_step + g.slab_h, axis=1)
        acc = _dw_taps(slab, w_tap, k, stride, rows, w_out)
        sc = (None if shortcut is None
              else shortcut[:, s * g.strip_h:s * g.strip_h + rows])
        strips.append(_collector(acc, eff_scale, eff_bias, sc, relu))
    return jnp.concatenate(strips, axis=1)


def conv2d_sparse_collector_ref(x_q: jax.Array, bitmap: jax.Array,
                                values: jax.Array, k: int, stride: int,
                                eff_scale: jax.Array, eff_bias: jax.Array,
                                shortcut=None, relu: bool = True) -> jax.Array:
    """Fused bitmap-native conv + Collector oracle (jnp lowering of
    kernels/conv_sparse.py; packed weights in, same epilogue maths)."""
    acc = conv2d_sparse_int8_ref(x_q, bitmap, values, k, stride)
    return _collector(acc, eff_scale, eff_bias, shortcut, relu)


def _collector(acc: jax.Array, eff_scale: jax.Array, eff_bias: jax.Array,
               shortcut, relu: bool) -> jax.Array:
    y = acc.astype(jnp.float32) * eff_scale + eff_bias
    if shortcut is not None:
        y = y + shortcut.astype(jnp.float32)
    return jnp.maximum(y, 0.0) if relu else y


def zero_counts_ref(y: jax.Array, group_size: int) -> dict:
    """Exact activation zero counts of a conv output (the sparsity-
    profiling oracle, observation-only — reads ``y``, changes nothing).

    y (N, H, W, C) f32 post-Collector output; channels split into
    C/group_size ``coarse_in`` lane groups (group i = channels
    [i*g, (i+1)*g), matching the kernels' channel-tile flattening).
    Returns the profiler aux dict (obs/sparsity.AUX_KEYS), all f32:
    per-image zero counts, per-group zero counts, per-group all-zero
    (image, pixel) cell counts, plus the static elems-per-row / cell
    totals the fractions divide by.
    """
    N, H, W, C = y.shape
    assert C % group_size == 0, (C, group_size)
    zm = y == 0.0
    z5 = zm.reshape(N, H, W, C // group_size, group_size)
    return {
        "row_zeros": jnp.sum(zm, axis=(1, 2, 3)).astype(jnp.float32),
        "group_zeros": jnp.sum(z5, axis=(0, 1, 2, 4)).astype(jnp.float32),
        "group_allzero": jnp.sum(jnp.all(z5, axis=4),
                                 axis=(0, 1, 2)).astype(jnp.float32),
        "elems_per_row": jnp.float32(H * W * C),
        "cells": jnp.float32(N * H * W),
    }


def flash_attention_ref(q, k, v, causal=True, window=None):
    """Naive softmax attention oracle for the chunked/flash paths.

    q,k,v: (B, H, T, D) (k/v may have fewer heads: GQA handled by caller).
    """
    T, S = q.shape[-2], k.shape[-2]
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(q.shape[-1])
    pos_q = jnp.arange(T)[:, None] + (S - T)
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, v)

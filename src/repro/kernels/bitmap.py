"""Shared on-chip bitmap-expand tile — the one piece of math every
bitmap-packed sparse kernel needs.

Format (core.compiled_linear.bitmap_pack):
  bitmap (K/8, N) uint8 — little-endian validity bits down the K axis
  values (keep_k, N) int8 — nonzero codes in ascending-row order per column

``expand_bitmap_tile`` turns one VMEM-resident slab of packed bytes into
dense int8 codes, carrying a running per-column nonzero count so callers
can stream the K axis in chunks (the cumsum is the hardware analogue of
the FPGA's compile-time wiring of nonzero adders).  Pure jnp, so the same
function body runs inside Pallas kernels (sparse_matvec, conv_sparse), in
interpret mode, and in the jnp oracles (kernels/ref.py) — HBM only ever
sees packed bytes on every lowering.
"""
from __future__ import annotations

import jax.numpy as jnp


def expand_bitmap_tile(bm8: jnp.ndarray, values: jnp.ndarray,
                       base: jnp.ndarray, keep_k: int):
    """Expand one bitmap slab to dense codes.

    bm8:    (rows8, n) uint8 — a K-chunk of the bitmap (rows8*8 K rows)
    values: (keep_k, n) int8 — the full packed-values buffer
    base:   (1, n) int32 — nonzeros consumed per column by earlier chunks
    Returns (w_chunk (rows8*8, n) int8, new_base (1, n) int32).
    """
    rows8, n = bm8.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    bits = (bm8[:, None, :] >> shifts) & 1
    mask = bits.reshape(rows8 * 8, n).astype(jnp.int32)
    pos = base + jnp.cumsum(mask, axis=0) - 1           # rank within column
    pos = jnp.clip(pos, 0, keep_k - 1)
    gathered = jnp.take_along_axis(values, pos, axis=0)
    w_chunk = jnp.where(mask > 0, gathered, jnp.int8(0))
    return w_chunk, base + jnp.sum(mask, axis=0, keepdims=True)

"""Block-sparse constant-weight matmul — Pallas TPU kernel.

"MACs associated with constant zeros are simply dropped" (paper SS II-A) at
the granularity a systolic array can drop them: whole (bk x bn) weight
blocks.  Because parameters are constants, the block mask is compile-time
metadata — the grid enumerates only the *active* blocks (zero blocks never
leave HBM, never touch the MXU), with the block coordinate list delivered
via scalar prefetch so BlockSpec index_maps can follow it.

Used for clustered sparse weights (core.sparsity.cluster_rows raises block
sparsity of 80%-unstructured weights) and for MoE expert block-diagonals.
Active blocks are ordered column-major (all k-blocks of output tile j
adjacent) so each output tile is initialized exactly once and revisited
contiguously.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(meta_ref, x_ref, wb_ref, out_ref, acc_ref):
    # meta rows: [k_block, n_block, is_first_for_n, is_last_for_n]
    i = pl.program_id(1)
    first = meta_ref[2, i]
    last = meta_ref[3, i]

    @pl.when(first == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], wb_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last == 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def plan_blocks(mask: np.ndarray) -> np.ndarray:
    """mask (Kb, Nb) bool -> meta (4, n_active) int32, column-major order."""
    ks, ns, firsts, lasts = [], [], [], []
    for nb in range(mask.shape[1]):
        active = np.nonzero(mask[:, nb])[0]
        for pos, kb in enumerate(active):
            ks.append(kb)
            ns.append(nb)
            firsts.append(1 if pos == 0 else 0)
            lasts.append(1 if pos == len(active) - 1 else 0)
    if not ks:  # degenerate: fully sparse
        return np.zeros((4, 0), np.int32)
    return np.stack([ks, ns, firsts, lasts]).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("block_kn", "n_blocks_n",
                                             "interpret"))
def block_sparse_matmul_pallas(x: jax.Array, w_blocks: jax.Array,
                               meta: jax.Array, block_kn: tuple,
                               n_blocks_n: int,
                               interpret: bool = False) -> jax.Array:
    """x (M, K) @ active blocks (n_active, bk, bn) -> (M, N).

    meta: (4, n_active) int32 from plan_blocks (device array; constant).
    Columns of the output whose block column has no active blocks are
    required to be absent from meta only if N tiles without work are
    zero-filled by the caller — kernels.ops handles that case.
    """
    M, K = x.shape
    bk, bn = block_kn
    n_active = w_blocks.shape[0]
    assert w_blocks.shape[1:] == (bk, bn) and meta.shape == (4, n_active)
    N = n_blocks_n * bn
    bm = min(128, M)
    assert M % bm == 0, (M, bm)
    grid = (M // bm, n_active)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda m, i, meta: (m, meta[0, i])),
                pl.BlockSpec((1, bk, bn), lambda m, i, meta: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda m, i, meta: (m, meta[1, i])),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(meta, x, w_blocks)
    return out

"""Implicit-GEMM fused convolution — Pallas TPU kernel (DESIGN.md §3).

The paper's Fig 1 Kernel + Non-Kernel decomposition as ONE kernel launch
per conv layer: the Kernel is the int8 x int7 MACs, the Non-Kernel
(Collector) — per-channel dequant, folded-BN scale, bias, shortcut add,
ReLU, and the output-amax needed to round activations back to 8 bits — is
fused into the epilogue.  The im2col patch tensor is never materialized
in HBM: each grid cell holds one (padded) input image in VMEM and forms
the k*k receptive-field taps *implicitly* as strided slices, issuing one
MXU matmul per tap:

    out[oh, ow, :] += x[oh*s + dy, ow*s + dx, :] @ w[dy, dx, :, :]

so HBM activation traffic is 1 byte/input-pixel instead of the 4*k*k
bytes/pixel of a materialized f32 patch tensor + separate-epilogue chain.

Grid: (N, C_out/bn).  Weights arrive in spatial-major layout
(k*k*c_in, c_out) so each tap's (c_in, bn) slab is a contiguous slice.
The whole padded image lives in VMEM per grid cell — right-sized for the
paper's ResNet50 feature maps (conv2_x at 56x56x256 int8 is ~0.8 MB;
the 224x224 stem has c_in=3).  Row-strip tiling for larger images is an
open item in ROADMAP.md.

Outputs: f32 (N, m_pad, C_out) conv result plus a per-(image, channel
tile) amax — max|y| reduced on-chip so the caller can requantize to int8
without re-reading the f32 output (the quantization-domain pass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def conv_tap_macs(x, k, stride, h_out, w_out, n_cols, tap_weights,
                  carry=None):
    """Implicit-im2col MAC loop shared by the dense and bitmap-native
    sparse conv kernels: one strided VMEM slice + MXU matmul per tap, the
    k*k loop unrolled at trace time (taps are static).

    ``tap_weights(tap, carry) -> ((C, n_cols) int8 slab, carry)`` supplies
    each tap's weight slab — a dense VMEM slice, or an on-chip bitmap
    expand threading its running nonzero count through ``carry``.
    """
    C = x.shape[-1]
    m_out = h_out * w_out
    acc = jnp.zeros((m_out, n_cols), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            sl = jax.lax.slice(
                x, (dy, dx, 0),
                (dy + (h_out - 1) * stride + 1,
                 dx + (w_out - 1) * stride + 1, C),
                (stride, stride, 1)).reshape(m_out, C)
            w_tap, carry = tap_weights(dy * k + dx, carry)
            acc += jax.lax.dot_general(
                sl, w_tap, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
    return acc


def collector_epilogue(acc, s_ref, b_ref, sc_ref, out_ref, amax_ref, *,
                       m_out, m_pad, relu):
    """Fused Collector: dequant * BN-scale (one vector), bias, shortcut,
    ReLU, on-chip amax.  One implementation shared by both conv kernels,
    so sparse and dense conv outputs are bit-identical by construction."""
    y = acc.astype(jnp.float32) * s_ref[...] + b_ref[...]
    if sc_ref is not None:
        y = y + sc_ref[0, :m_out, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    amax_ref[0, 0] = jnp.max(jnp.abs(y))
    if m_pad > m_out:
        y = jnp.pad(y, ((0, m_pad - m_out), (0, 0)))
    out_ref[0] = y


def _kernel(*refs, k, stride, h_out, w_out, m_pad, relu, has_shortcut):
    if has_shortcut:
        x_ref, w_ref, s_ref, b_ref, sc_ref, out_ref, amax_ref = refs
    else:
        x_ref, w_ref, s_ref, b_ref, out_ref, amax_ref = refs
        sc_ref = None
    x = x_ref[0]                                   # (Hp, Wp, C) int8, VMEM
    C = x.shape[-1]
    tap_weights = lambda tap, carry: (w_ref[tap * C:(tap + 1) * C, :], carry)
    acc = conv_tap_macs(x, k, stride, h_out, w_out, w_ref.shape[1],
                        tap_weights)
    collector_epilogue(acc, s_ref, b_ref, sc_ref, out_ref, amax_ref,
                       m_out=h_out * w_out, m_pad=m_pad, relu=relu)


@functools.partial(jax.jit, static_argnames=(
    "k", "stride", "h_out", "w_out", "bn", "relu", "interpret"))
def conv2d_implicit_pallas(x_pad: jax.Array, w_sp: jax.Array,
                           eff_scale: jax.Array, eff_bias: jax.Array,
                           shortcut: jax.Array | None = None, *,
                           k: int, stride: int, h_out: int, w_out: int,
                           bn: int = 128, relu: bool = True,
                           interpret: bool = False):
    """Fused implicit-GEMM conv.

    x_pad:     (N, Hp, Wp, C) int8, already SAME-padded (ref.pad_same_nhwc)
    w_sp:      (k*k*C, n_out) int8, spatial-major tap layout
    eff_scale: (1, n_out) f32 = s_x * w_scale * bn_scale (whole dequant+BN)
    eff_bias:  (1, n_out) f32
    shortcut:  optional (N, m_pad, n_out) f32, m_pad = h_out*w_out rounded
               up to a sublane multiple
    Returns (y, amax): y f32 (N, m_pad, n_out); amax f32 (N, n_out/bn)
    per-(image, channel-tile) max|y| for the int8 requantization pass.
    """
    N, Hp, Wp, C = x_pad.shape
    KK, n_out = w_sp.shape
    assert KK == k * k * C and n_out % bn == 0, ((KK, k, C), (n_out, bn))
    assert Hp >= (h_out - 1) * stride + k and Wp >= (w_out - 1) * stride + k
    m_out = h_out * w_out
    m_pad = -(-m_out // 8) * 8
    n_j = n_out // bn
    kern = functools.partial(_kernel, k=k, stride=stride, h_out=h_out,
                             w_out=w_out, m_pad=m_pad, relu=relu,
                             has_shortcut=shortcut is not None)
    in_specs = [
        pl.BlockSpec((1, Hp, Wp, C), lambda n, j: (n, 0, 0, 0)),
        pl.BlockSpec((KK, bn), lambda n, j: (0, j)),
        pl.BlockSpec((1, bn), lambda n, j: (0, j)),
        pl.BlockSpec((1, bn), lambda n, j: (0, j)),
    ]
    args = [x_pad, w_sp, eff_scale, eff_bias]
    if shortcut is not None:
        assert shortcut.shape == (N, m_pad, n_out), shortcut.shape
        in_specs.append(pl.BlockSpec((1, m_pad, bn), lambda n, j: (n, 0, j)))
        args.append(shortcut.astype(jnp.float32))
    y, amax = pl.pallas_call(
        kern,
        grid=(N, n_j),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, m_pad, bn), lambda n, j: (n, 0, j)),
                   pl.BlockSpec((1, 1), lambda n, j: (n, j))],
        out_shape=[jax.ShapeDtypeStruct((N, m_pad, n_out), jnp.float32),
                   jax.ShapeDtypeStruct((N, n_j), jnp.float32)],
        interpret=interpret,
    )(*args)
    return y, amax

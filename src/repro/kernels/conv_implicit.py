"""Implicit-GEMM fused convolution — Pallas TPU kernel (DESIGN.md §3).

The paper's Fig 1 Kernel + Non-Kernel decomposition as ONE kernel launch
per conv layer: the Kernel is the int8 x int7 MACs, the Non-Kernel
(Collector) — per-channel dequant, folded-BN scale, bias, shortcut add,
ReLU, and the output-amax needed to round activations back to 8 bits — is
fused into the epilogue.  The im2col patch tensor is never materialized
in HBM: each grid cell holds a row strip of the (padded) input image in
VMEM and forms the k*k receptive-field taps *implicitly* as strided
slices, issuing one MXU matmul per tap:

    out[oh, ow, :] += x[oh*s + dy, ow*s + dx, :] @ w[dy, dx, :, :]

so HBM activation traffic is 1 byte/input-pixel instead of the 4*k*k
bytes/pixel of a materialized f32 patch tensor + separate-epilogue chain.

Grid: (N, n_strips, C_out/bn) — the paper's persistent line-buffer
streaming as row-strip tiling (kernels/tiling.py).  Each cell holds a
(slab_h, Wp, C) int8 slab, slab_h = (strip_h-1)*stride + k, read at an
Unblocked row offset so consecutive strips overlap by their k-stride
halo rows; the per-cell VMEM working set is bounded by the strip planner
instead of growing with image height (7x7 maps degenerate to one strip —
exactly the pre-tiling kernel).  Weights arrive in spatial-major layout
(k*k*c_in, c_out), stored that way at compile time, so each tap's
(c_in, bn) slab is a contiguous slice with no call-time permute.

Outputs: f32 (N, n_strips*ms_pad, C_out) strip-blocked conv result plus a
per-(image, strip, channel tile) amax — max|y| over the strip's valid
rows, reduced on-chip so the caller can requantize to int8 without
re-reading the f32 output (the quantization-domain pass); the caller
max-reduces over strips, which equals the whole-image amax exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import strip_geometry


def conv_tap_macs(x, k, stride, h_out, w_out, n_cols, tap_weights,
                  carry=None):
    """Implicit-im2col MAC loop shared by the dense and bitmap-native
    sparse conv kernels: one strided VMEM slice + MXU matmul per tap, the
    k*k loop unrolled at trace time (taps are static).  ``x`` is any
    padded slab covering rows [0, (h_out-1)*stride + k) — a whole image
    or one halo'd row strip; the loop is identical either way.

    ``tap_weights(tap, carry) -> ((C, n_cols) int8 slab, carry)`` supplies
    each tap's weight slab — a dense VMEM slice, or an on-chip bitmap
    expand threading its running nonzero count through ``carry``.
    """
    C = x.shape[-1]
    m_out = h_out * w_out
    acc = jnp.zeros((m_out, n_cols), jnp.int32)
    for dy in range(k):
        for dx in range(k):
            sl = jax.lax.slice(
                x, (dy, dx, 0),
                (dy + (h_out - 1) * stride + 1,
                 dx + (w_out - 1) * stride + 1, C),
                (stride, stride, 1)).reshape(m_out, C)
            w_tap, carry = tap_weights(dy * k + dx, carry)
            acc += jax.lax.dot_general(
                sl, w_tap, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
    return acc


def collector_epilogue(acc, s_ref, b_ref, sc_ref, out_ref, amax_ref, *,
                       m_out, m_pad, relu, valid_rows=None,
                       zero_refs=None, group_size=None):
    """Fused Collector: dequant * BN-scale (one vector), bias, shortcut,
    ReLU, on-chip amax.  One implementation shared by both conv kernels,
    so sparse and dense conv outputs are bit-identical by construction.

    ``valid_rows`` masks the amax to the strip's real output rows: the
    last strip of a tiled launch computes surplus rows from zero-padded
    input (sliced off by the caller) whose bias/ReLU values must not leak
    into the quantization scale.

    ``zero_refs`` (opt-in sparsity profiling, DESIGN.md §11) is a
    ``(zg_ref, za_ref)`` pair of per-cell output refs: the epilogue also
    counts this strip-tile's zero elements per ``group_size``-channel
    ``coarse_in`` group and its all-zero-group (row) cells — masked to
    the same valid rows as the amax, so surplus strip rows never count.
    Observation-only: ``y`` itself is untouched, so profiled and
    unprofiled launches stay bit-identical (tested).
    """
    y = acc.astype(jnp.float32) * s_ref[...] + b_ref[...]
    if sc_ref is not None:
        y = y + sc_ref[0, :m_out, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    ay = jnp.abs(y)
    rows = (None if valid_rows is None else
            jax.lax.broadcasted_iota(jnp.int32, ay.shape, 0))
    if rows is not None:
        ay = jnp.where(rows < valid_rows, ay, 0.0)
    amax_ref[0, 0, 0] = jnp.max(ay)
    if zero_refs is not None:
        zg_ref, za_ref = zero_refs
        zm = y == 0.0
        if rows is not None:
            zm = zm & (rows < valid_rows)
        z3 = zm.reshape(m_out, y.shape[1] // group_size, group_size)
        zg_ref[0, 0, 0, :] = jnp.sum(z3, axis=(0, 2)).astype(jnp.float32)
        za_ref[0, 0, 0, :] = jnp.sum(jnp.all(z3, axis=2),
                                     axis=0).astype(jnp.float32)
    if m_pad > m_out:
        y = jnp.pad(y, ((0, m_pad - m_out), (0, 0)))
    out_ref[0] = y


def _kernel(*refs, k, stride, strip_h, h_out, w_out, ms_pad, relu,
            has_shortcut, profile_g):
    n_in = 5 if has_shortcut else 4
    ins, outs = refs[:n_in], refs[n_in:]
    if has_shortcut:
        x_ref, w_ref, s_ref, b_ref, sc_ref = ins
    else:
        x_ref, w_ref, s_ref, b_ref = ins
        sc_ref = None
    out_ref, amax_ref = outs[0], outs[1]
    zero_refs = (outs[2], outs[3]) if profile_g else None
    x = x_ref[0]                                # (slab_h, Wp, C) int8, VMEM
    C = x.shape[-1]
    tap_weights = lambda tap, carry: (w_ref[tap * C:(tap + 1) * C, :], carry)
    acc = conv_tap_macs(x, k, stride, strip_h, w_out, w_ref.shape[1],
                        tap_weights)
    valid = jnp.minimum(strip_h, h_out - pl.program_id(1) * strip_h) * w_out
    collector_epilogue(acc, s_ref, b_ref, sc_ref, out_ref, amax_ref,
                       m_out=strip_h * w_out, m_pad=ms_pad, relu=relu,
                       valid_rows=valid, zero_refs=zero_refs,
                       group_size=profile_g)


@functools.partial(jax.jit, static_argnames=(
    "k", "stride", "h_out", "w_out", "bn", "strip_h", "relu", "interpret",
    "profile_g"))
def conv2d_implicit_pallas(x_pad: jax.Array, w_sp: jax.Array,
                           eff_scale: jax.Array, eff_bias: jax.Array,
                           shortcut: jax.Array | None = None, *,
                           k: int, stride: int, h_out: int, w_out: int,
                           bn: int = 128, strip_h: int | None = None,
                           relu: bool = True, interpret: bool = False,
                           profile_g: int | None = None):
    """Fused row-strip-tiled implicit-GEMM conv.

    x_pad:     (N, Hp, Wp, C) int8, SAME-padded (ref.pad_same_nhwc) and
               bottom-padded with zero rows to the strip plan's x_rows
    w_sp:      (k*k*C, n_out) int8, spatial-major tap layout (the
               compile-time storage layout — no call-time permute)
    eff_scale: (N, n_out) f32 = s_x[row] * w_scale * bn_scale (whole
               dequant+BN), one row per image: per-row quantization
               domains index it on the grid's image axis (a per-tensor
               scalar domain broadcasts the same row N times)
    eff_bias:  (1, n_out) f32
    shortcut:  optional (N, n_strips*ms_pad, n_out) f32, strip-blocked
               (each strip's strip_h*w_out rows padded to ms_pad)
    strip_h:   output rows per strip; None = one whole-image strip
    profile_g: opt-in sparsity profiling — coarse_in group size (must
               divide bn); appends two per-(image, strip, channel-tile,
               group) f32 zero-count outputs (elements / all-zero row
               cells over valid rows) to the return, observation-only
    Returns (y, amax): y f32 (N, n_strips*ms_pad, C_out) strip-blocked;
    amax f32 (N, n_strips, n_out/bn) per-(image, strip, channel-tile)
    max|y| over valid rows for the int8 requantization pass — or
    (y, amax, zg, za) with ``profile_g``.
    """
    N, Hp, Wp, C = x_pad.shape
    KK, n_out = w_sp.shape
    assert KK == k * k * C and n_out % bn == 0, ((KK, k, C), (n_out, bn))
    assert eff_scale.shape == (N, n_out), (eff_scale.shape, N, n_out)
    g = strip_geometry(k=k, stride=stride, h_out=h_out, w_out=w_out,
                       strip_h=strip_h if strip_h is not None else h_out)
    assert Hp >= g.x_rows and Wp >= (w_out - 1) * stride + k, \
        ((Hp, Wp), g.x_rows)
    n_j = n_out // bn
    kern = functools.partial(_kernel, k=k, stride=stride, strip_h=g.strip_h,
                             h_out=h_out, w_out=w_out, ms_pad=g.ms_pad,
                             relu=relu, has_shortcut=shortcut is not None,
                             profile_g=profile_g)
    in_specs = [
        # overlapping halo'd slabs: Unblocked = element-offset indexing
        pl.BlockSpec((1, g.slab_h, Wp, C),
                     lambda n, s, j: (n, s * g.row_step, 0, 0),
                     indexing_mode=pl.unblocked),
        pl.BlockSpec((KK, bn), lambda n, s, j: (0, j)),
        # eff_scale: one dequant row PER IMAGE (per-row quant domains)
        pl.BlockSpec((1, bn), lambda n, s, j: (n, j)),
        pl.BlockSpec((1, bn), lambda n, s, j: (0, j)),
    ]
    args = [x_pad, w_sp, eff_scale, eff_bias]
    if shortcut is not None:
        assert shortcut.shape == (N, g.n_strips * g.ms_pad, n_out), \
            (shortcut.shape, g)
        in_specs.append(
            pl.BlockSpec((1, g.ms_pad, bn), lambda n, s, j: (n, s, j)))
        args.append(shortcut.astype(jnp.float32))
    out_specs = [pl.BlockSpec((1, g.ms_pad, bn), lambda n, s, j: (n, s, j)),
                 pl.BlockSpec((1, 1, 1), lambda n, s, j: (n, s, j))]
    out_shape = [jax.ShapeDtypeStruct((N, g.n_strips * g.ms_pad, n_out),
                                      jnp.float32),
                 jax.ShapeDtypeStruct((N, g.n_strips, n_j), jnp.float32)]
    if profile_g:
        assert bn % profile_g == 0, (bn, profile_g)
        gpb = bn // profile_g
        out_specs += [pl.BlockSpec((1, 1, 1, gpb),
                                   lambda n, s, j: (n, s, j, 0))] * 2
        out_shape += [jax.ShapeDtypeStruct((N, g.n_strips, n_j, gpb),
                                           jnp.float32)] * 2
    outs = pl.pallas_call(
        kern,
        grid=(N, g.n_strips, n_j),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return tuple(outs)

"""Backend dispatch for the Pallas kernels.

On TPU the Pallas kernels run natively; everywhere else (this CPU
container, and the multi-pod dry-run) the mathematically identical jnp
references lower instead — same dtypes, same sharding, so compiled HLO
stays representative.  Set REPRO_PALLAS=interpret to force the kernels
through Pallas interpret mode (used by the kernel test-suite).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "jnp", "tpu"):
        return env
    return "tpu" if jax.default_backend() == "tpu" else "jnp"


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def cfmm_matmul(x_q: jax.Array, codes: jax.Array,
                scale: jax.Array | None = None) -> jax.Array:
    """int8 (M,K) @ int8 (K,N) -> int32 (or f32 with scale fused)."""
    mode = _mode()
    if mode == "jnp":
        if scale is None:
            return ref.int8_matmul_ref(x_q, codes)
        return ref.cfmm_matmul_ref(x_q, codes, scale)
    from repro.kernels.cfmm_matmul import cfmm_matmul_pallas
    interpret = mode == "interpret"
    M, K = x_q.shape
    N = codes.shape[1]
    bm = 128 if M >= 128 else max(8, 1 << (M - 1).bit_length())
    bk = min(512, K) if K % 512 == 0 else _largest_tile(K, 512)
    bn = 128 if N % 128 == 0 else _largest_tile(N, 128)
    xp, _ = _pad_to(x_q, 0, bm)
    s = scale if scale is not None else jnp.ones((1, N), jnp.float32)
    out = cfmm_matmul_pallas(xp, codes, s, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)[:M]
    if scale is None:
        return out.astype(jnp.int32)
    return out


def _largest_tile(dim: int, cap: int) -> int:
    for t in range(min(cap, dim), 0, -1):
        if dim % t == 0:
            return t
    return 1


def sparse_cfmm_matmul(x_q: jax.Array, bitmap: jax.Array,
                       values: jax.Array,
                       scale: jax.Array | None = None) -> jax.Array:
    """Bitmap-packed sparse matmul; int32 out (or f32 with scale fused)."""
    mode = _mode()
    if bitmap.shape[0] * 8 != x_q.shape[1]:
        # K padded to a multiple of 8 at compile time (masked tail rows);
        # zero int8 activations are exact, so pad x to match
        assert bitmap.shape[0] * 8 == -(-x_q.shape[1] // 8) * 8, (
            bitmap.shape, x_q.shape)
        x_q, _ = _pad_to(x_q, 1, 8)
    if mode == "jnp":
        acc = ref.sparse_matvec_ref(x_q, bitmap, values)
        if scale is None:
            return acc
        return acc.astype(jnp.float32) * scale
    from repro.kernels.sparse_matvec import sparse_matvec_pallas
    interpret = mode == "interpret"
    M, K = x_q.shape
    N = bitmap.shape[1]
    bn = 128 if N % 128 == 0 else _largest_tile(N, 128)
    k_chunk = _largest_tile(K, 1024)
    if k_chunk % 8 != 0:
        k_chunk = K  # single chunk fallback
    s = scale if scale is not None else jnp.ones((1, N), jnp.float32)
    out = sparse_matvec_pallas(x_q, bitmap, values, s, bn=bn,
                               k_chunk=k_chunk, interpret=interpret)
    if scale is None:
        return out.astype(jnp.int32)
    return out


def block_sparse_matmul(x: jax.Array, w: jax.Array,
                        block_kn: tuple = (128, 128)) -> jax.Array:
    """x (M,K) @ w (K,N) skipping all-zero constant blocks.

    w must be a *concrete* array (constant parameters) — the block mask and
    active-block plan are built at trace time, so zero blocks are dropped
    from the grid entirely (the paper's dropped MACs).
    """
    from repro.core.sparsity import block_mask
    from repro.kernels.block_sparse import (block_sparse_matmul_pallas,
                                            plan_blocks)
    assert not isinstance(w, jax.core.Tracer), (
        "block_sparse_matmul requires constant weights")
    bk, bn = block_kn
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0, ((K, N), block_kn)
    mask = block_mask(w, (bk, bn))
    wnp = np.asarray(w)
    blocks = []
    for nb in range(mask.shape[1]):
        for kb in np.nonzero(mask[:, nb])[0]:
            blocks.append(wnp[kb * bk:(kb + 1) * bk, nb * bn:(nb + 1) * bn])
    meta = plan_blocks(mask)
    mode = _mode()
    if mode == "jnp" or meta.shape[1] == 0:
        w_dense = jnp.asarray(np.where(
            np.kron(mask, np.ones((bk, bn), bool)), wnp, 0))
        return (x @ w_dense.astype(x.dtype))
    w_blocks = jnp.asarray(np.stack(blocks))
    M = x.shape[0]
    bm = min(128, M)
    xp, _ = _pad_to(x, 0, bm)
    out = block_sparse_matmul_pallas(
        xp, w_blocks.astype(x.dtype), jnp.asarray(meta), (bk, bn),
        N // bn, interpret=(mode == "interpret"))[:M]
    col_has_work = np.repeat(mask.any(axis=0), bn)
    return jnp.where(jnp.asarray(col_has_work)[None, :], out, 0)


def conv2d(x_q: jax.Array, codes: jax.Array, k: int, stride: int, *,
           x_scale, w_scale: jax.Array, gamma: jax.Array | None = None,
           beta: jax.Array | None = None, shortcut: jax.Array | None = None,
           relu: bool = True, quant_out: bool = False):
    """Fused implicit-GEMM int8 SAME conv + Collector epilogue.

    x_q:     (N, H, W, c_in) int8 activations, x_scale their scalar scale
    codes:   (c_in*k*k, c_out) int8 constant weight codes in patch
             (channel-major) order — the layout ``compile_params`` stores
             — OR a packed ``(bitmap, values)`` pair in the spatial-major
             bitmap-native layout (kernels/conv_sparse.py): the
             sparse_cfmm fast path, where packed bytes reach the kernel
             and the dense weight never exists outside VMEM
    w_scale: per-output-channel dequant scale, broadcastable to (c_out,)
    gamma/beta: folded-BN scale and bias (the Non-Kernel Collector ops)
    shortcut:   optional f32 (N, h_out, w_out, c_out) residual to add
    quant_out:  round the output back to int8 (paper: "saturated and
                rounded to 8 bits") -> returns (y_q int8, y_scale);
                otherwise returns f32 (N, h_out, w_out, c_out).

    Lowering follows REPRO_PALLAS like every op here: the jnp reference on
    CPU, the Pallas implicit-GEMM kernel on TPU / in interpret mode.
    """
    mode = _mode()
    N, H, W, C = x_q.shape
    packed = isinstance(codes, (tuple, list))
    if packed:
        bitmap, values = codes
        n_out = bitmap.shape[1]
        assert bitmap.shape[0] * 8 == -(-C * k * k // 8) * 8, (
            bitmap.shape, C, k)
    else:
        n_out = codes.shape[1]
        assert codes.shape[0] == C * k * k, (codes.shape, C, k)
    one = jnp.ones((n_out,), jnp.float32)
    eff_scale = (jnp.asarray(x_scale, jnp.float32)
                 * w_scale.reshape(-1).astype(jnp.float32)
                 * (one if gamma is None else gamma.astype(jnp.float32)))
    eff_bias = (jnp.zeros((n_out,), jnp.float32) if beta is None
                else beta.astype(jnp.float32))
    if mode == "jnp":
        if packed:
            y = ref.conv2d_sparse_collector_ref(
                x_q, bitmap, values, k, stride, eff_scale, eff_bias,
                shortcut, relu)
        else:
            y = ref.conv2d_collector_ref(x_q, codes, k, stride, eff_scale,
                                         eff_bias, shortcut, relu)
        amax_of = lambda: jnp.max(jnp.abs(y))
    else:
        xp, h_out, w_out = ref.pad_same_nhwc(x_q, k, stride)
        m_out, m_pad = h_out * w_out, -(-h_out * w_out // 8) * 8
        bn = 128 if n_out % 128 == 0 else _largest_tile(n_out, 128)
        sc = None
        if shortcut is not None:
            sc = shortcut.astype(jnp.float32).reshape(N, m_out, n_out)
            sc = jnp.pad(sc, ((0, 0), (0, m_pad - m_out), (0, 0)))
        kw = dict(k=k, stride=stride, h_out=h_out, w_out=w_out, bn=bn,
                  relu=relu, interpret=(mode == "interpret"))
        if packed:
            from repro.kernels.conv_sparse import conv2d_sparse_pallas
            y_flat, _amax = conv2d_sparse_pallas(
                xp, bitmap, values, eff_scale.reshape(1, n_out),
                eff_bias.reshape(1, n_out), sc, **kw)
        else:
            from repro.kernels.conv_implicit import conv2d_implicit_pallas
            w_sp = codes.reshape(C, k, k, n_out).transpose(1, 2, 0, 3)
            y_flat, _amax = conv2d_implicit_pallas(
                xp, w_sp.reshape(k * k * C, n_out),
                eff_scale.reshape(1, n_out), eff_bias.reshape(1, n_out),
                sc, **kw)
        y = y_flat[:, :m_out, :].reshape(N, h_out, w_out, n_out)
        amax_of = lambda: jnp.max(_amax)   # reduced on-chip in the epilogue
    if not quant_out:
        return y
    # quantization-domain pass: activations go straight back to int8 so
    # the next conv consumes codes without an f32 HBM round-trip
    s_y = (jnp.maximum(amax_of(), 1e-12) / 127.0).astype(jnp.float32)
    y_q = jnp.clip(jnp.round(y / s_y), -127, 127).astype(jnp.int8)
    return y_q, s_y


def flash_attention(q, k, v, causal=True, window=None):
    """GQA-native flash attention: Pallas on TPU, jnp chunked elsewhere.

    q: (B, KVH, G, Tq, D); k: (B, KVH, Tk, D); v: (B, KVH, Tk, Dv).
    """
    mode = _mode()
    if mode == "jnp":
        from repro.models.attention import flash_attention as jnp_flash
        return jnp_flash(q, k, v, causal=causal, window=window)
    from repro.kernels.flash_attention import flash_attention_pallas
    B, KVH, G, Tq, D = q.shape
    Tk = k.shape[2]
    bq = _largest_tile(Tq, 128)
    bk = _largest_tile(Tk, 128)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk,
                                  interpret=(mode == "interpret"))

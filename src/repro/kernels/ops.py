"""Backend dispatch for the Pallas kernels.

On TPU the Pallas kernels run natively; everywhere else (this CPU
container, and the multi-pod dry-run) the mathematically identical jnp
references lower instead — same dtypes, same sharding, so compiled HLO
stays representative.  Set REPRO_PALLAS=interpret to force the kernels
through Pallas interpret mode (used by the kernel test-suite).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "jnp", "tpu"):
        return env
    return "tpu" if jax.default_backend() == "tpu" else "jnp"


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def cfmm_matmul(x_q: jax.Array, codes: jax.Array,
                scale: jax.Array | None = None) -> jax.Array:
    """int8 (M,K) @ int8 (K,N) -> int32 (or f32 with scale fused)."""
    mode = _mode()
    if mode == "jnp":
        if scale is None:
            return ref.int8_matmul_ref(x_q, codes)
        return ref.cfmm_matmul_ref(x_q, codes, scale)
    from repro.kernels.cfmm_matmul import cfmm_matmul_pallas
    interpret = mode == "interpret"
    M, K = x_q.shape
    N = codes.shape[1]
    bm = 128 if M >= 128 else max(8, 1 << (M - 1).bit_length())
    bk = min(512, K) if K % 512 == 0 else _largest_tile(K, 512)
    bn = 128 if N % 128 == 0 else _largest_tile(N, 128)
    xp, _ = _pad_to(x_q, 0, bm)
    s = scale if scale is not None else jnp.ones((1, N), jnp.float32)
    out = cfmm_matmul_pallas(xp, codes, s, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)[:M]
    if scale is None:
        return out.astype(jnp.int32)
    return out


def _largest_tile(dim: int, cap: int) -> int:
    for t in range(min(cap, dim), 0, -1):
        if dim % t == 0:
            return t
    return 1


def sparse_cfmm_matmul(x_q: jax.Array, bitmap: jax.Array,
                       values: jax.Array,
                       scale: jax.Array | None = None) -> jax.Array:
    """Bitmap-packed sparse matmul; int32 out (or f32 with scale fused)."""
    mode = _mode()
    if mode == "jnp":
        acc = ref.sparse_matvec_ref(x_q, bitmap, values)
        if scale is None:
            return acc
        return acc.astype(jnp.float32) * scale
    from repro.kernels.sparse_matvec import sparse_matvec_pallas
    interpret = mode == "interpret"
    M, K = x_q.shape
    N = bitmap.shape[1]
    bn = 128 if N % 128 == 0 else _largest_tile(N, 128)
    k_chunk = _largest_tile(K, 1024)
    if k_chunk % 8 != 0:
        k_chunk = K  # single chunk fallback
    s = scale if scale is not None else jnp.ones((1, N), jnp.float32)
    out = sparse_matvec_pallas(x_q, bitmap, values, s, bn=bn,
                               k_chunk=k_chunk, interpret=interpret)
    if scale is None:
        return out.astype(jnp.int32)
    return out


def block_sparse_matmul(x: jax.Array, w: jax.Array,
                        block_kn: tuple = (128, 128)) -> jax.Array:
    """x (M,K) @ w (K,N) skipping all-zero constant blocks.

    w must be a *concrete* array (constant parameters) — the block mask and
    active-block plan are built at trace time, so zero blocks are dropped
    from the grid entirely (the paper's dropped MACs).
    """
    from repro.core.sparsity import block_mask
    from repro.kernels.block_sparse import (block_sparse_matmul_pallas,
                                            plan_blocks)
    assert not isinstance(w, jax.core.Tracer), (
        "block_sparse_matmul requires constant weights")
    bk, bn = block_kn
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0, ((K, N), block_kn)
    mask = block_mask(w, (bk, bn))
    wnp = np.asarray(w)
    blocks = []
    for nb in range(mask.shape[1]):
        for kb in np.nonzero(mask[:, nb])[0]:
            blocks.append(wnp[kb * bk:(kb + 1) * bk, nb * bn:(nb + 1) * bn])
    meta = plan_blocks(mask)
    mode = _mode()
    if mode == "jnp" or meta.shape[1] == 0:
        w_dense = jnp.asarray(np.where(
            np.kron(mask, np.ones((bk, bn), bool)), wnp, 0))
        return (x @ w_dense.astype(x.dtype))
    w_blocks = jnp.asarray(np.stack(blocks))
    M = x.shape[0]
    bm = min(128, M)
    xp, _ = _pad_to(x, 0, bm)
    out = block_sparse_matmul_pallas(
        xp, w_blocks.astype(x.dtype), jnp.asarray(meta), (bk, bn),
        N // bn, interpret=(mode == "interpret"))[:M]
    col_has_work = np.repeat(mask.any(axis=0), bn)
    return jnp.where(jnp.asarray(col_has_work)[None, :], out, 0)


def flash_attention(q, k, v, causal=True, window=None):
    """GQA-native flash attention: Pallas on TPU, jnp chunked elsewhere.

    q: (B, KVH, G, Tq, D); k: (B, KVH, Tk, D); v: (B, KVH, Tk, Dv).
    """
    mode = _mode()
    if mode == "jnp":
        from repro.models.attention import flash_attention as jnp_flash
        return jnp_flash(q, k, v, causal=causal, window=window)
    from repro.kernels.flash_attention import flash_attention_pallas
    B, KVH, G, Tq, D = q.shape
    Tk = k.shape[2]
    bq = _largest_tile(Tq, 128)
    bk = _largest_tile(Tk, 128)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk,
                                  interpret=(mode == "interpret"))

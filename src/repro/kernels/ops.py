"""Backend dispatch for the Pallas kernels.

On TPU the Pallas kernels run natively; everywhere else (this CPU
container, and the multi-pod dry-run) the mathematically identical jnp
references lower instead — same dtypes, same sharding, so compiled HLO
stays representative.  Set REPRO_PALLAS=interpret to force the kernels
through Pallas interpret mode (used by the kernel test-suite).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref, tiling


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "jnp", "tpu"):
        return env
    return "tpu" if jax.default_backend() == "tpu" else "jnp"


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def cfmm_matmul(x_q: jax.Array, codes: jax.Array,
                scale: jax.Array | None = None) -> jax.Array:
    """int8 (M,K) @ int8 (K,N) -> int32 (or f32 with scale fused)."""
    mode = _mode()
    if mode == "jnp":
        if scale is None:
            return ref.int8_matmul_ref(x_q, codes)
        return ref.cfmm_matmul_ref(x_q, codes, scale)
    from repro.kernels.cfmm_matmul import cfmm_matmul_pallas
    interpret = mode == "interpret"
    M, K = x_q.shape
    N = codes.shape[1]
    bm = 128 if M >= 128 else max(8, 1 << (M - 1).bit_length())
    bk, k_pad = _tile_pad(K, 512)
    bn, n_pad = _tile_pad(N, 128)
    xp, _ = _pad_to(x_q, 0, bm)
    s = scale if scale is not None else jnp.ones((1, N), jnp.float32)
    if k_pad > K:                  # zero rows/cols: exact under int8 matmul
        xp = jnp.pad(xp, ((0, 0), (0, k_pad - K)))
        codes = jnp.pad(codes, ((0, k_pad - K), (0, 0)))
    if n_pad > N:
        codes = jnp.pad(codes, ((0, 0), (0, n_pad - N)))
        s = jnp.pad(s, ((0, 0), (0, n_pad - N)))
    out = cfmm_matmul_pallas(xp, codes, s, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)[:M, :N]
    if scale is None:
        return out.astype(jnp.int32)
    return out


def _largest_tile(dim: int, cap: int) -> int:
    for t in range(min(cap, dim), 0, -1):
        if dim % t == 0:
            return t
    return 1


def _tile_pad(dim: int, cap: int) -> tuple[int, int]:
    """(tile, padded_dim) for a lane-tiled axis: one tile when the axis
    fits the cap, else the largest clean divisor.  When an awkward axis
    would degrade toward one grid cell per element — the old
    ``_largest_tile`` pathology: a prime gives tile 1, and 8*prime a
    sliver tile of 8 — pad the axis to the next cap multiple instead and
    let the caller slice the result; zero pad rows/columns are exact
    under int8 matmul.  A divisor tile is kept only when it is both a
    sublane multiple and a reasonable fraction (>= 1/4) of the cap."""
    if dim <= cap:
        return dim, dim
    t = _largest_tile(dim, cap)
    if t % 8 == 0 and t >= cap // 4:
        return t, dim
    return cap, -(-dim // cap) * cap


def sparse_cfmm_matmul(x_q: jax.Array, bitmap: jax.Array,
                       values: jax.Array,
                       scale: jax.Array | None = None) -> jax.Array:
    """Bitmap-packed sparse matmul; int32 out (or f32 with scale fused)."""
    mode = _mode()
    if bitmap.shape[0] * 8 != x_q.shape[1]:
        # K padded to a multiple of 8 at compile time (masked tail rows);
        # zero int8 activations are exact, so pad x to match
        assert bitmap.shape[0] * 8 == -(-x_q.shape[1] // 8) * 8, (
            bitmap.shape, x_q.shape)
        x_q, _ = _pad_to(x_q, 1, 8)
    if mode == "jnp":
        acc = ref.sparse_matvec_ref(x_q, bitmap, values)
        if scale is None:
            return acc
        return acc.astype(jnp.float32) * scale
    from repro.kernels.sparse_matvec import sparse_matvec_pallas
    interpret = mode == "interpret"
    M, K = x_q.shape
    N = bitmap.shape[1]
    bn, n_pad = _tile_pad(N, 128)
    k_chunk = _largest_tile(K, 1024)
    if k_chunk % 8 != 0:
        k_chunk = K  # single chunk fallback
    s = scale if scale is not None else jnp.ones((1, N), jnp.float32)
    if n_pad > N:                  # zero bitmap bytes expand to zero codes
        bitmap = jnp.pad(bitmap, ((0, 0), (0, n_pad - N)))
        values = jnp.pad(values, ((0, 0), (0, n_pad - N)))
        s = jnp.pad(s, ((0, 0), (0, n_pad - N)))
    out = sparse_matvec_pallas(x_q, bitmap, values, s, bn=bn,
                               k_chunk=k_chunk, interpret=interpret)[:, :N]
    if scale is None:
        return out.astype(jnp.int32)
    return out


def block_sparse_matmul(x: jax.Array, w: jax.Array,
                        block_kn: tuple = (128, 128)) -> jax.Array:
    """x (M,K) @ w (K,N) skipping all-zero constant blocks.

    w must be a *concrete* array (constant parameters) — the block mask and
    active-block plan are built at trace time, so zero blocks are dropped
    from the grid entirely (the paper's dropped MACs).
    """
    from repro.core.sparsity import block_mask
    from repro.kernels.block_sparse import (block_sparse_matmul_pallas,
                                            plan_blocks)
    assert not isinstance(w, jax.core.Tracer), (
        "block_sparse_matmul requires constant weights")
    bk, bn = block_kn
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0, ((K, N), block_kn)
    mask = block_mask(w, (bk, bn))
    wnp = np.asarray(w)
    blocks = []
    for nb in range(mask.shape[1]):
        for kb in np.nonzero(mask[:, nb])[0]:
            blocks.append(wnp[kb * bk:(kb + 1) * bk, nb * bn:(nb + 1) * bn])
    meta = plan_blocks(mask)
    mode = _mode()
    if mode == "jnp" or meta.shape[1] == 0:
        w_dense = jnp.asarray(np.where(
            np.kron(mask, np.ones((bk, bn), bool)), wnp, 0))
        return (x @ w_dense.astype(x.dtype))
    w_blocks = jnp.asarray(np.stack(blocks))
    M = x.shape[0]
    bm = min(128, M)
    xp, _ = _pad_to(x, 0, bm)
    out = block_sparse_matmul_pallas(
        xp, w_blocks.astype(x.dtype), jnp.asarray(meta), (bk, bn),
        N // bn, interpret=(mode == "interpret"))[:M]
    col_has_work = np.repeat(mask.any(axis=0), bn)
    return jnp.where(jnp.asarray(col_has_work)[None, :], out, 0)


def _strip_blocked(sc_flat: jax.Array, plan, n_pad: int) -> jax.Array:
    """(N, m_out, n_out) f32 -> the tiled kernels' strip-blocked layout
    (N, n_strips*ms_pad, n_pad): each strip's ms rows padded to the
    sublane multiple (and channels to the lane tile) with zeros."""
    N, m_out, n_out = sc_flat.shape
    sc = jnp.pad(sc_flat, ((0, 0), (0, plan.n_strips * plan.ms - m_out),
                           (0, n_pad - n_out)))
    sc = sc.reshape(N, plan.n_strips, plan.ms, n_pad)
    sc = jnp.pad(sc, ((0, 0), (0, 0), (0, plan.ms_pad - plan.ms), (0, 0)))
    return sc.reshape(N, plan.n_strips * plan.ms_pad, n_pad)


def conv2d(x_q: jax.Array, codes: jax.Array, k: int, stride: int, *,
           x_scale, w_scale: jax.Array, gamma: jax.Array | None = None,
           beta: jax.Array | None = None, shortcut: jax.Array | None = None,
           relu: bool = True, quant_out: bool = False,
           w_layout: str = "channel", strip_h: int | None = None,
           zero_count: int | None = None):
    """Fused row-strip-tiled implicit-GEMM int8 SAME conv + Collector.

    x_q:     (N, H, W, c_in) int8 activations; x_scale their scale —
             a scalar (per-tensor quantization domain) or an ``(N,)``
             per-row vector (one domain per image, DESIGN.md §9).  The
             domain shape propagates: with a per-row x_scale, quant_out
             emits a per-row y_scale, so a chain of convs stays per-row
             end to end and a row's results never depend on its batch
             neighbours
    codes:   (c_in*k*k, c_out) int8 constant weight codes — in im2col
             patch (channel-major) order by default, or the compiled
             spatial-major tap order with ``w_layout="spatial"`` (what
             ``compile_params`` stores for every dense conv leaf, so the
             serving path pays zero call-time layout shuffles) — OR a
             packed ``(bitmap, values)`` pair in the spatial-major
             bitmap-native layout (kernels/conv_sparse.py): the
             sparse_cfmm fast path, where packed bytes reach the kernel
             and the dense weight never exists outside VMEM
    w_scale: per-output-channel dequant scale, broadcastable to (c_out,)
    gamma/beta: folded-BN scale and bias (the Non-Kernel Collector ops)
    shortcut:   optional f32 (N, h_out, w_out, c_out) residual to add
    quant_out:  round the output back to int8 (paper: "saturated and
                rounded to 8 bits") -> returns (y_q int8, y_scale);
                otherwise returns f32 (N, h_out, w_out, c_out).
    strip_h: row-strip override (tests/benchmarks force awkward strip
             boundaries); None lets kernels/tiling.py pick the largest
             strip whose VMEM working set fits the budget.  Tiled and
             untiled outputs are bit-identical; the jnp lowering only
             loops strips when strip_h is forced.
    zero_count: opt-in activation-sparsity profiling (DESIGN.md §11) —
             the coarse_in group size to count zeros at.  Appends the
             profiler aux dict (kernels/ref.zero_counts_ref keys) to the
             return: ``(y, zc)`` or ``(y_q, y_scale, zc)``.  jnp lowers
             to the exact recount on ``y``; the Pallas kernels emit a
             cheap per-strip zero-count output alongside the amax (host
             recount fallback when channel padding misaligns the
             groups).  Observation-only — y/y_q bits are unchanged.

    Lowering follows REPRO_PALLAS like every op here: the jnp reference on
    CPU, the Pallas implicit-GEMM kernel on TPU / in interpret mode.
    """
    mode = _mode()
    N, H, W, C = x_q.shape
    packed = isinstance(codes, (tuple, list))
    if packed:
        bitmap, values = codes
        n_out = bitmap.shape[1]
        assert bitmap.shape[0] * 8 == -(-C * k * k // 8) * 8, (
            bitmap.shape, C, k)
    else:
        n_out = codes.shape[1]
        assert codes.shape[0] == C * k * k, (codes.shape, C, k)
    one = jnp.ones((n_out,), jnp.float32)
    x_s = jnp.asarray(x_scale, jnp.float32)
    per_row = x_s.ndim >= 1          # (N,) per-row domains vs scalar
    col_scale = (w_scale.reshape(-1).astype(jnp.float32)
                 * (one if gamma is None else gamma.astype(jnp.float32)))
    # (R, n_out), R = N for per-row domains, 1 for the per-tensor scalar
    eff_scale = x_s.reshape(-1, 1) * col_scale.reshape(1, -1)
    eff_bias = (jnp.zeros((n_out,), jnp.float32) if beta is None
                else beta.astype(jnp.float32))
    profile_fast = False          # in-kernel zero counts (Pallas only)
    if mode == "jnp":
        # (R, 1, 1, n_out) broadcasts against NHWC accumulators in the
        # oracles' shared _collector, per-row and per-tensor alike
        eff4 = eff_scale.reshape(eff_scale.shape[0], 1, 1, n_out)
        if strip_h is not None:
            y = ref.conv2d_collector_strips_ref(
                x_q, codes, k, stride, strip_h, eff4, eff_bias,
                shortcut, relu, layout=w_layout)
        elif packed:
            y = ref.conv2d_sparse_collector_ref(
                x_q, bitmap, values, k, stride, eff4, eff_bias,
                shortcut, relu)
        else:
            y = ref.conv2d_collector_ref(x_q, codes, k, stride, eff4,
                                         eff_bias, shortcut, relu,
                                         layout=w_layout)
        amax_of = (lambda: jnp.max(jnp.abs(y), axis=(1, 2, 3))) if per_row \
            else (lambda: jnp.max(jnp.abs(y)))
    else:
        xp, h_out, w_out = ref.pad_same_nhwc(x_q, k, stride)
        m_out = h_out * w_out
        bn, n_pad = _tile_pad(n_out, 128)
        if n_pad > n_out:          # awkward channel count: zero-pad + slice
            if packed:
                bitmap = jnp.pad(bitmap, ((0, 0), (0, n_pad - n_out)))
                values = jnp.pad(values, ((0, 0), (0, n_pad - n_out)))
            else:
                codes = jnp.pad(codes, ((0, 0), (0, n_pad - n_out)))
            eff_scale = jnp.pad(eff_scale, ((0, 0), (0, n_pad - n_out)))
            eff_bias = jnp.pad(eff_bias, (0, n_pad - n_out))
        if packed:                 # per-cell weight slab for the planner:
            weight_bytes = (bitmap.shape[0] + values.shape[0]) * bn
            if C % 8 != 0:         # + the one-shot expanded slab (stem)
                weight_bytes += bitmap.shape[0] * 8 * bn
        else:
            weight_bytes = k * k * C * bn
        plan = tiling.plan_strips(k=k, stride=stride, h_out=h_out,
                                  w_out=w_out, wp=xp.shape[2], c_in=C,
                                  bn=bn, weight_bytes=weight_bytes,
                                  has_shortcut=shortcut is not None,
                                  strip_h=strip_h)
        if xp.shape[1] < plan.x_rows:  # zero rows for the last strip's slab
            xp = jnp.pad(xp, ((0, 0), (0, plan.x_rows - xp.shape[1]),
                              (0, 0), (0, 0)))
        sc = None
        if shortcut is not None:
            sc = _strip_blocked(
                shortcut.astype(jnp.float32).reshape(N, m_out, n_out),
                plan, n_pad)
        # profiling rides the kernel launch (a per-strip zero-count
        # output next to the amax) when the padded channel axis keeps
        # coarse_in groups aligned; otherwise fall back to an exact
        # host-side recount on y below (padded channels are all-zero and
        # would inflate the counts)
        profile_fast = (zero_count is not None and n_pad == n_out
                        and n_out % zero_count == 0
                        and bn % zero_count == 0)
        kw = dict(k=k, stride=stride, h_out=h_out, w_out=w_out, bn=bn,
                  strip_h=plan.strip_h, relu=relu,
                  interpret=(mode == "interpret"),
                  profile_g=zero_count if profile_fast else None)
        # the kernels index eff_scale per image (grid axis n) so per-row
        # domains ride the same launch; a per-tensor scalar broadcasts
        eff_rows = jnp.broadcast_to(eff_scale, (N, n_pad))
        if packed:
            from repro.kernels.conv_sparse import conv2d_sparse_pallas
            outs = conv2d_sparse_pallas(
                xp, bitmap, values, eff_rows,
                eff_bias.reshape(1, n_pad), sc, **kw)
        else:
            from repro.kernels.conv_implicit import conv2d_implicit_pallas
            if w_layout == "channel":  # pre-compile codes pay the permute
                codes = ref.to_spatial_major(codes, k, C)
            outs = conv2d_implicit_pallas(
                xp, codes, eff_rows,
                eff_bias.reshape(1, n_pad), sc, **kw)
        y_flat, _amax = outs[0], outs[1]
        y = y_flat.reshape(N, plan.n_strips, plan.ms_pad, n_pad)[
            :, :, :plan.ms, :n_out]
        y = y.reshape(N, plan.n_strips * plan.ms, n_out)[:, :m_out]
        y = y.reshape(N, h_out, w_out, n_out)
        # reduced on-chip in the epilogue: (N, n_strips, n_j) -> whole-
        # tensor max, or max over strips/tiles only (keep N) per-row
        amax_of = (lambda: jnp.max(_amax, axis=(1, 2))) if per_row \
            else (lambda: jnp.max(_amax))
    zc = None
    if zero_count is not None:
        if profile_fast:
            # kernel outputs: (N, n_strips, n_j, groups/tile) valid-row
            # zero counts; flatten (tile, in-tile group) -> the global
            # channel-group axis and reduce on the right axes
            m_out = y.shape[1] * y.shape[2]
            zg = outs[2].reshape(N, -1, n_out // zero_count)
            za = outs[3].reshape(N, -1, n_out // zero_count)
            zc = {"row_zeros": jnp.sum(zg, axis=(1, 2)),
                  "group_zeros": jnp.sum(zg, axis=(0, 1)),
                  "group_allzero": jnp.sum(za, axis=(0, 1)),
                  "elems_per_row": jnp.float32(m_out * n_out),
                  "cells": jnp.float32(N * m_out)}
        else:
            zc = ref.zero_counts_ref(y, zero_count)
    if not quant_out:
        return (y, zc) if zero_count is not None else y
    # quantization-domain pass: activations go straight back to int8 so
    # the next conv consumes codes without an f32 HBM round-trip; under
    # per-row domains s_y is (N,) — one independent scale per image
    s_y = (jnp.maximum(amax_of(), 1e-12) / 127.0).astype(jnp.float32)
    s_b = s_y.reshape(-1, 1, 1, 1) if per_row else s_y
    y_q = jnp.clip(jnp.round(y / s_b), -127, 127).astype(jnp.int8)
    if zero_count is not None:
        return y_q, s_y, zc
    return y_q, s_y


def conv2d_dw(x_q: jax.Array, values: jax.Array, k: int, stride: int, *,
              x_scale, w_scale: jax.Array, gamma: jax.Array | None = None,
              beta: jax.Array | None = None,
              shortcut: jax.Array | None = None, relu: bool = True,
              quant_out: bool = False, strip_h: int | None = None,
              zero_count: int | None = None):
    """Fused row-strip-tiled depthwise int8 SAME conv + Collector.

    The depthwise sibling of ``conv2d`` (same Collector semantics, same
    quantization-domain contract: per-row ``x_scale`` propagates to a
    per-row ``y_scale`` under ``quant_out``).  ``values`` is the
    compile-time tap-major ``(k*k, C)`` int8 weight — one weight row per
    receptive-field tap — consumed by the VPU tap-MAC kernel
    (kernels/conv_depthwise.py); implicit-GEMM would burn a (C, C)
    matmul per tap for a diagonal's worth of useful work.  jnp lowering
    and Pallas kernel are bit-identical across strip tilings (the jnp
    path loops strips only when ``strip_h`` is forced, like ``conv2d``).
    """
    mode = _mode()
    N, H, W, C = x_q.shape
    assert values.shape == (k * k, C), (values.shape, k, C)
    one = jnp.ones((C,), jnp.float32)
    x_s = jnp.asarray(x_scale, jnp.float32)
    per_row = x_s.ndim >= 1          # (N,) per-row domains vs scalar
    col_scale = (w_scale.reshape(-1).astype(jnp.float32)
                 * (one if gamma is None else gamma.astype(jnp.float32)))
    eff_scale = x_s.reshape(-1, 1) * col_scale.reshape(1, -1)
    eff_bias = (jnp.zeros((C,), jnp.float32) if beta is None
                else beta.astype(jnp.float32))
    profile_fast = False
    if mode == "jnp":
        eff4 = eff_scale.reshape(eff_scale.shape[0], 1, 1, C)
        if strip_h is not None:
            y = ref.conv2d_dw_collector_strips_ref(
                x_q, values, k, stride, strip_h, eff4, eff_bias,
                shortcut, relu)
        else:
            y = ref.conv2d_dw_collector_ref(x_q, values, k, stride, eff4,
                                            eff_bias, shortcut, relu)
        amax_of = (lambda: jnp.max(jnp.abs(y), axis=(1, 2, 3))) if per_row \
            else (lambda: jnp.max(jnp.abs(y)))
    else:
        xp, h_out, w_out = ref.pad_same_nhwc(x_q, k, stride)
        m_out = h_out * w_out
        bn, n_pad = _tile_pad(C, 128)
        if n_pad > C:              # awkward channel count: zero-pad + slice
            # zero input channels x zero weight channels -> zero outputs,
            # exact under int8 MACs; the pad is sliced off below
            xp = jnp.pad(xp, ((0, 0), (0, 0), (0, 0), (0, n_pad - C)))
            values = jnp.pad(values, ((0, 0), (0, n_pad - C)))
            eff_scale = jnp.pad(eff_scale, ((0, 0), (0, n_pad - C)))
            eff_bias = jnp.pad(eff_bias, (0, n_pad - C))
        # the slab is channel-tiled (bn channels per cell), so the
        # planner's activation term scales with bn, not C
        plan = tiling.plan_strips(k=k, stride=stride, h_out=h_out,
                                  w_out=w_out, wp=xp.shape[2], c_in=bn,
                                  bn=bn, weight_bytes=k * k * bn,
                                  has_shortcut=shortcut is not None,
                                  strip_h=strip_h)
        if xp.shape[1] < plan.x_rows:  # zero rows for the last strip's slab
            xp = jnp.pad(xp, ((0, 0), (0, plan.x_rows - xp.shape[1]),
                              (0, 0), (0, 0)))
        sc = None
        if shortcut is not None:
            sc = _strip_blocked(
                shortcut.astype(jnp.float32).reshape(N, m_out, C),
                plan, n_pad)
        profile_fast = (zero_count is not None and n_pad == C
                        and C % zero_count == 0
                        and bn % zero_count == 0)
        eff_rows = jnp.broadcast_to(eff_scale, (N, n_pad))
        from repro.kernels.conv_depthwise import conv2d_dw_pallas
        outs = conv2d_dw_pallas(
            xp, values, eff_rows, eff_bias.reshape(1, n_pad), sc,
            k=k, stride=stride, h_out=h_out, w_out=w_out, bn=bn,
            strip_h=plan.strip_h, relu=relu,
            interpret=(mode == "interpret"),
            profile_g=zero_count if profile_fast else None)
        y_flat, _amax = outs[0], outs[1]
        y = y_flat.reshape(N, plan.n_strips, plan.ms_pad, n_pad)[
            :, :, :plan.ms, :C]
        y = y.reshape(N, plan.n_strips * plan.ms, C)[:, :m_out]
        y = y.reshape(N, h_out, w_out, C)
        amax_of = (lambda: jnp.max(_amax, axis=(1, 2))) if per_row \
            else (lambda: jnp.max(_amax))
    zc = None
    if zero_count is not None:
        if profile_fast:
            m_out = y.shape[1] * y.shape[2]
            zg = outs[2].reshape(N, -1, C // zero_count)
            za = outs[3].reshape(N, -1, C // zero_count)
            zc = {"row_zeros": jnp.sum(zg, axis=(1, 2)),
                  "group_zeros": jnp.sum(zg, axis=(0, 1)),
                  "group_allzero": jnp.sum(za, axis=(0, 1)),
                  "elems_per_row": jnp.float32(m_out * C),
                  "cells": jnp.float32(N * m_out)}
        else:
            zc = ref.zero_counts_ref(y, zero_count)
    if not quant_out:
        return (y, zc) if zero_count is not None else y
    s_y = (jnp.maximum(amax_of(), 1e-12) / 127.0).astype(jnp.float32)
    s_b = s_y.reshape(-1, 1, 1, 1) if per_row else s_y
    y_q = jnp.clip(jnp.round(y / s_b), -127, 127).astype(jnp.int8)
    if zero_count is not None:
        return y_q, s_y, zc
    return y_q, s_y


def flash_attention(q, k, v, causal=True, window=None):
    """GQA-native flash attention: Pallas on TPU, jnp chunked elsewhere.

    q: (B, KVH, G, Tq, D); k: (B, KVH, Tk, D); v: (B, KVH, Tk, Dv).
    """
    mode = _mode()
    if mode == "jnp":
        from repro.models.attention import flash_attention as jnp_flash
        return jnp_flash(q, k, v, causal=causal, window=window)
    from repro.kernels.flash_attention import flash_attention_pallas
    B, KVH, G, Tq, D = q.shape
    Tk = k.shape[2]
    bq = _largest_tile(Tq, 128)
    bk = _largest_tile(Tk, 128)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk,
                                  interpret=(mode == "interpret"))

"""The pjit-able training and serving step functions.

These are what launch/dryrun.py lowers for every (arch x shape x mesh)
cell and what launch/train.py / serving/engine.py execute for real.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.training import optimizer
from repro.training.grad_compression import compress_decompress


def train_step(params, opt_state, batch, *, cfg: ArchConfig,
               opt_cfg: optimizer.OptConfig, qat: bool = False,
               grad_compress: str = "none"):
    """One optimizer step.  params: raw value pytree; returns
    (params, opt_state, metrics)."""

    def loss_of(p):
        logits, aux = lm.forward_train(p, batch, cfg, qat=qat)
        loss, metrics = lm.loss_fn(logits, batch["labels"], aux)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
    if grad_compress != "none":
        grads = compress_decompress(grads, method=grad_compress)
    new_params, new_opt, opt_metrics = optimizer.apply_updates(
        params, grads, opt_state, opt_cfg)
    metrics = {**metrics, **opt_metrics, "loss": loss}
    return new_params, new_opt, metrics


def make_train_step(cfg: ArchConfig, opt_cfg=None, qat=False,
                    grad_compress="none"):
    opt_cfg = opt_cfg or optimizer.OptConfig()
    return functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg, qat=qat,
                             grad_compress=grad_compress)


def prefill_step(params, cache, batch, *, cfg: ArchConfig):
    logits, cache = lm.forward_prefill(params, batch, cfg, cache)
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return token, cache


def serve_step(params, cache, batch, *, cfg: ArchConfig):
    """One decode step: greedy next token + advanced cache."""
    logits, cache = lm.forward_decode(params, batch, cfg, cache)
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return token, cache


def make_serve_step(cfg: ArchConfig, kind="decode"):
    fn = serve_step if kind == "decode" else prefill_step
    return functools.partial(fn, cfg=cfg)

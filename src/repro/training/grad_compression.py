"""Gradient compression with error feedback (distributed-optimization
substrate for the 1000+-node regime).

int8 stochastic-free symmetric quantization per leaf: the all-reduce then
moves 4x fewer bytes (bf16 grads) / 8x (f32).  compress_decompress is the
in-graph QDQ form — under pjit the compiler reduces the quantized tensor.
A persistent error-feedback buffer variant is provided for the training
loop (launch/train.py) to accumulate quantization residuals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q(g, bits=8):
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / qmax
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, method="int8"):
    """QDQ each gradient leaf (int8 symmetric per-tensor)."""
    if method == "none":
        return grads

    def qdq(g):
        if g.ndim < 2:
            return g
        q, s = _q(g.astype(jnp.float32))
        return (q.astype(jnp.float32) * s).astype(g.dtype)

    return jax.tree.map(qdq, grads)


def init_error_feedback(grads_shape):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                        grads_shape)


def compress_with_feedback(grads, errors):
    """Error-feedback compression: g' = Q(g + e); e' = (g + e) - g'."""
    def one(g, e):
        if g.ndim < 2:
            return g, e
        tot = g.astype(jnp.float32) + e
        q, s = _q(tot)
        deq = q.astype(jnp.float32) * s
        return deq.astype(g.dtype), tot - deq

    out = jax.tree.map(one, grads, errors)
    comp = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return comp, errs

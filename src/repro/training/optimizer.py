"""AdamW + schedules, built directly on pytrees (no optax dependency).

Optimizer state mirrors the parameter tree leaf-for-leaf, so the same
logical-axis sharding rules apply (m/v shard exactly like their param —
ZeRO-style when params are FSDP-sharded over 'data').
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params_values) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                         params_values)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def lr_at(step, cfg: OptConfig):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """AdamW step.  params/grads: raw value pytrees (same structure)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.betas
    lr = lr_at(state.step, cfg)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** step)
        vhat = v2 / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}

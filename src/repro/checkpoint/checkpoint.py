"""Fault-tolerant checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json   — tree structure, shapes, dtypes, shard map,
                              sha256 of each blob, writer process count
            arrays_<proc>.npz
         <dir>/LATEST       — atomically-updated pointer

Properties needed at 1000+-node scale, emulated faithfully here:
  * atomic publish: blobs + manifest written to step_N.tmp, fsync'd,
    renamed; LATEST updated last -> a crash mid-save never corrupts the
    restore point;
  * integrity: every blob hashed; restore verifies before use;
  * multi-writer: each process saves only the shards it owns
    (process_index-suffixed npz) — on this single-process container that
    degenerates to one file;
  * elastic restore: arrays are saved unsharded-logically (per-shard
    files concatenate along the sharded axis recorded in the manifest),
    so a restart may use a different mesh — resharding happens when the
    restored tree is device_put with the new sharding rules;
  * retention: keep_last newest checkpoints are retained.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return names, vals, treedef


def save(ckpt_dir, step: int, tree, keep_last: int = 3,
         process_index: int = 0, blocking: bool = True):
    """Save a pytree checkpoint.  Returns the checkpoint path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"

    def _write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        names, vals, _ = _flatten(tree)
        arrays = {}
        meta = {}
        for name, v in zip(names, vals):
            arr = np.asarray(v)
            meta[name] = dict(shape=list(arr.shape), dtype=str(arr.dtype))
            if arr.dtype.name == "bfloat16":  # npz has no bf16: view as u16
                arr = arr.view(np.uint16)
            arrays[name] = arr
        blob = tmp / f"arrays_{process_index}.npz"
        np.savez(blob, **arrays)
        with open(blob, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest = dict(step=step, names=names, meta=meta,
                        blobs={f"arrays_{process_index}.npz": digest},
                        n_processes=jax.process_count(),
                        time=time.time())
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        os.replace(tmp, final)
        latest = ckpt_dir / "LATEST"
        latest_tmp = ckpt_dir / "LATEST.tmp"
        latest_tmp.write_text(final.name)
        os.replace(latest_tmp, latest)
        _retain(ckpt_dir, keep_last)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return final, t
    return final


def _retain(ckpt_dir: pathlib.Path, keep_last: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    latest = ckpt_dir / "LATEST"
    if not latest.exists():
        return None
    name = latest.read_text().strip()
    if not (ckpt_dir / name / "manifest.json").exists():
        # LATEST points at a corrupt/missing save: fall back to newest valid
        cands = sorted(p.name for p in ckpt_dir.glob("step_*") if
                       (p / "manifest.json").exists())
        if not cands:
            return None
        name = cands[-1]
    return int(name.split("_")[1])


def restore(ckpt_dir, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like`` (values replaced)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    arrays = {}
    for blob, digest in manifest["blobs"].items():
        data = (path / blob).read_bytes()
        actual = hashlib.sha256(data).hexdigest()
        if actual != digest:
            raise IOError(f"checkpoint blob {blob} corrupt "
                          f"(sha256 {actual} != {digest})")
        with np.load(path / blob) as z:
            arrays.update({k: z[k] for k in z.files})
    names, vals, treedef = _flatten(tree_like)
    missing = [n for n in names if n not in arrays]
    if missing:
        raise KeyError(f"checkpoint missing {len(missing)} leaves, "
                       f"e.g. {missing[:3]}")
    import ml_dtypes
    meta = manifest["meta"]
    new_vals = []
    for n, v in zip(names, vals):
        arr = arrays[n]
        if meta[n]["dtype"] == "bfloat16":    # stored as a u16 view
            arr = arr.view(ml_dtypes.bfloat16)
        new_vals.append(jax.numpy.asarray(arr).astype(v.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_vals), step

"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2 x 16 x 16 = 512 chips with a leading
pure-DP 'pod' axis (DCN-connected pods; only gradient all-reduces cross
the pod boundary).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    assert len(devices) >= n, (
        f"need {n} devices, have {len(devices)} — run under "
        f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dry run) "
        f"or on the full slice")
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CI-grade sharding tests (needs >= prod(shape) devices)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def pipeline_stage_devices(n_stages: int, devices=None) -> list:
    """Device list for the pipeline-parallel CNN serving path: one device
    per stage, in a 1-D 'stage' chain (the Fig 7 chip line re-expressed
    over local accelerators).  With fewer physical devices than stages,
    stages wrap round-robin — correctness is placement-independent (only
    throughput changes), which is what lets the whole pipeline degenerate
    to one CPU device in tests.  Fan a CPU host out to N devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=N.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    return [devices[s % len(devices)] for s in range(n_stages)]


def replica_pipeline_devices(n_replicas: int, n_stages: int,
                             devices=None) -> list:
    """Disjoint per-replica device groups for the replicated serving
    front-end (serving/frontend.py): ``n_replicas`` independent stage
    chains of ``n_stages`` devices each, carved contiguously from the
    local device list — replica ``r`` owns devices
    ``[r*n_stages, (r+1)*n_stages)``, so no device (and no resident
    weight byte) is shared between replicas when ``n_replicas*n_stages``
    physical devices exist.  With fewer devices the groups wrap
    round-robin, exactly like ``pipeline_stage_devices`` — correctness
    is placement-independent (only throughput changes), so the whole
    fleet degenerates to one CPU device in tests.  Fan a CPU host out
    with XLA_FLAGS=--xla_force_host_platform_device_count=N.
    """
    assert n_replicas >= 1 and n_stages >= 1, (n_replicas, n_stages)
    devices = list(jax.devices()) if devices is None else list(devices)
    return [[devices[(r * n_stages + s) % len(devices)]
             for s in range(n_stages)] for r in range(n_replicas)]

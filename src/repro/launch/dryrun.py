import os

os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. materializes parameter/optimizer/cache shapes via jax.eval_shape
     (no allocation — ShapeDtypeStructs only);
  3. jit-lowers train_step (train shapes) or serve/prefill_step (inference
     shapes) with NamedShardings from the logical-axis rules;
  4. .compile()s, records memory_analysis / cost_analysis / parsed
     collectives into experiments/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
  python -m repro.launch.dryrun --arch smollm_360m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --jobs 4
"""
import argparse
import functools
import json
import pathlib
import subprocess
import sys
import time
import traceback

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             serve_mode: str = "cfmm", variant: str = "baseline",
             extra: dict | None = None, rules_name: str | None = None,
             kv_dtype: str | None = None) -> dict:
    import jax
    import jax.numpy as jnp
    from repro import nn
    from repro.configs.base import (SHAPES, cell_applicable, get_config,
                                    input_specs)
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.models import lm
    from repro.roofline import analysis
    from repro.training import optimizer, train_step as ts

    cfg = get_config(arch)
    if extra:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(functools.partial(lm.init, cfg=cfg), key)
    n_params = analysis.count_params_from_shapes(params_shapes)
    n_active = analysis.active_param_count(cfg, n_params)
    batch_specs = input_specs(cfg, shape_name)
    step = shape["step"]

    with mesh:
        if step == "train":
            rules = shd.RULES_BY_NAME[rules_name or "train"]
            p_shard = shd.param_shardings(params_shapes, mesh, rules)
            opt_shapes = jax.eval_shape(optimizer.init,
                                        nn.unbox(params_shapes))
            o_shard = optimizer.OptState(
                jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                p_shard, p_shard)
            dp_axes = (("pod", "data", "model")
                       if rules_name == "dp_only" else ("pod", "data"))
            b_shard = shd.batch_shardings(batch_specs, mesh, dp_axes)
            fn = ts.make_train_step(cfg)
            with shd.use_rules(rules):
                lowered = jax.jit(
                    fn, in_shardings=(p_shard, o_shard, b_shard),
                    donate_argnums=(0, 1),
                ).lower(nn.unbox(params_shapes), opt_shapes, batch_specs)
        else:
            rules = shd.RULES_BY_NAME[rules_name or "serve"]
            from repro.core.compiled_linear import compile_params
            serve_shapes = jax.eval_shape(
                functools.partial(compile_params, mode=serve_mode),
                params_shapes)
            p_shard = shd.param_shardings(serve_shapes, mesh, rules)
            S_max = shape["seq"]
            B = shape["batch"]
            cache_shapes = jax.eval_shape(
                functools.partial(lm.cache_init, cfg, B, S_max,
                                  S_enc=(1500 if cfg.encoder_decoder and
                                         step == "decode" else
                                         (shape["seq"] if cfg.encoder_decoder
                                          else None)),
                                  kv_dtype=(jnp.int8 if kv_dtype == "int8"
                                            else None)))
            c_shard = shd.param_shardings(cache_shapes, mesh, rules)
            b_shard = shd.batch_shardings(batch_specs, mesh)
            fn = ts.make_serve_step(cfg, kind=step)
            with shd.use_rules(rules):
                lowered = jax.jit(
                    fn, in_shardings=(p_shard, c_shard, b_shard),
                    donate_argnums=(1,),
                ).lower(nn.unbox(serve_shapes), nn.unbox(cache_shapes),
                        batch_specs)
        compiled = lowered.compile()

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    mflops = analysis.model_flops_for(cfg, n_params, n_active, shape, step)
    roof = analysis.from_compiled(compiled, chips, mflops)
    coll = analysis.parse_collectives(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape_name, "step": step,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "variant": variant, "rules": rules_name, "kv_dtype": kv_dtype,
        "serve_mode": serve_mode if step != "train" else None,
        "n_params": n_params, "n_active_params": n_active,
        "compile_s": round(time.time() - t0, 1),
        "memory_analysis": mem,
        "collectives": coll,
        "roofline": roof.to_dict(),
    }
    return rec


def artifact_path(arch, shape_name, multi_pod, variant="baseline"):
    mesh_dir = "multi" if multi_pod else "single"
    sub = ART_DIR / mesh_dir
    sub.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    return sub / f"{arch}__{shape_name}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--serve-mode", default="cfmm")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        from repro.configs.base import ARCH_IDS, SHAPES
        jobs = []
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in meshes:
                    out = artifact_path(arch, shape, mp, args.variant)
                    if out.exists() and not args.force:
                        continue
                    jobs.append((arch, shape, mp))
        print(f"dryrun: {len(jobs)} cells to compile")
        procs = []
        while jobs or procs:
            while jobs and len(procs) < args.jobs:
                arch, shape, mp = jobs.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", "multi" if mp else "single",
                       "--serve-mode", args.serve_mode,
                       "--variant", args.variant]
                procs.append(((arch, shape, mp),
                              subprocess.Popen(cmd)))
            done = [(k, p) for k, p in procs if p.poll() is not None]
            for k, p in done:
                procs.remove((k, p))
                status = "ok" if p.returncode == 0 else f"FAIL rc={p.returncode}"
                print(f"  {k[0]}/{k[1]}/{'multi' if k[2] else 'single'}: {status}",
                      flush=True)
            time.sleep(1.0)
        return

    assert args.arch and args.shape
    mp = args.mesh == "multi"
    try:
        rec = run_cell(args.arch, args.shape, mp, args.serve_mode,
                       args.variant, rules_name=args.rules,
                       extra={"unroll": True} if args.unroll else None,
                       kv_dtype=args.kv_dtype)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "multi" if mp else "single", "variant": args.variant,
               "error": traceback.format_exc()}
    out = artifact_path(args.arch, args.shape, mp, args.variant)
    out.write_text(json.dumps(rec, indent=1, default=float))
    print(json.dumps({k: rec.get(k) for k in
                      ("arch", "shape", "mesh", "skipped", "compile_s")},
                     default=float))
    if "error" in rec:
        print(rec["error"], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""End-to-end training driver with fault tolerance.

Features exercised even on this 1-core container (tiny presets), designed
for the 1000+-node regime:
  * checkpoint/restart: atomic saves every --ckpt-every steps; --resume
    restores the latest valid checkpoint (survives --fail-at-step crashes);
  * deterministic data: stream position == step, so restarts replay
    nothing and skip nothing;
  * straggler watchdog: per-step wall time vs EMA; steps slower than
    --straggler-factor x EMA are logged (on a real cluster this feeds the
    controller that re-shards around slow hosts);
  * gradient compression: --grad-compress int8 (error-feedback variant in
    training/grad_compression.py);
  * elastic scaling: checkpoints are mesh-agnostic (full logical arrays),
    so a restart may use a different device count / mesh shape.

Usage:
  python -m repro.launch.train --arch smollm_360m --preset tiny --steps 200
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticDataset
from repro.models import lm
from repro.training import optimizer
from repro.training.train_step import make_train_step

PRESETS = {
    # (layers, d_model, heads, kv, head_dim, d_ff, vocab, seq, batch)
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 head_dim=32, d_ff=256, vocab=512),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=8192),
    "full": {},
}


def build_cfg(arch: str, preset: str):
    cfg = get_config(arch)
    over = dict(PRESETS[preset])
    if preset != "full" and cfg.moe is not None:
        over["moe"] = dataclasses.replace(cfg.moe, n_experts=8,
                                          top_k=min(cfg.moe.top_k, 2),
                                          d_ff_expert=over["d_ff"] // 4)
    if preset != "full" and cfg.ssm is not None:
        if cfg.ssm.kind == "mamba":
            over["ssm"] = dataclasses.replace(cfg.ssm, d_inner=2 * over["d_model"],
                                              d_state=8, dt_rank=16)
        else:
            over["ssm"] = dataclasses.replace(cfg.ssm, head_dim=32)
    if preset != "full" and cfg.mla is not None:
        from repro.configs.base import MLAConfig
        over["mla"] = MLAConfig(kv_lora=64, qk_nope=32, qk_rope=16, v_dim=32)
        over["head_dim"] = 48
    if preset != "full" and cfg.encoder_decoder:
        over["n_enc_layers"] = 2
        over["dec_len"] = 32
    return dataclasses.replace(cfg, **over) if over else cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="crash deliberately (fault-tolerance demo)")
    ap.add_argument("--grad-compress", default="none",
                    choices=("none", "int8"))
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--qat", action="store_true",
                    help="INT7 fake-quant QAT (train a compilable model)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = build_cfg(args.arch, args.preset)
    opt_cfg = optimizer.OptConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps)
    data = SyntheticDataset(DataConfig(cfg.vocab, args.seq, args.batch),
                            jax.process_index(), jax.process_count())

    key = jax.random.PRNGKey(0)
    params = nn.unbox(lm.init(key, cfg))
    opt_state = optimizer.init(params)
    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step = ckpt.restore(
            args.ckpt_dir, (params, opt_state))
        print(f"[train] resumed from step {start_step}", flush=True)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, qat=args.qat,
                                      grad_compress=args.grad_compress),
                      donate_argnums=(0, 1))

    ema = None
    t_hist = []
    for step in range(start_step, args.steps):
        if step == args.fail_at_step:
            print(f"[train] injected failure at step {step}", flush=True)
            sys.exit(42)
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics = jax.tree.map(float, metrics)
        dt = time.time() - t0
        t_hist.append(dt)
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if step > 2 and dt > args.straggler_factor * ema:
            print(f"[watchdog] step {step} straggled: {dt:.2f}s vs "
                  f"EMA {ema:.2f}s", flush=True)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss={metrics['loss']:.4f} "
                  f"ce={metrics['ce']:.4f} gnorm={metrics['grad_norm']:.2f} "
                  f"lr={metrics['lr']:.2e} {dt:.2f}s", flush=True)
        if (args.ckpt_dir and args.ckpt_every > 0
                and (step + 1) % args.ckpt_every == 0):
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state))
            print(f"[ckpt] saved step {step + 1}", flush=True)
    if data.cfg.source == "markov":
        print(f"[train] final ce={metrics['ce']:.4f} "
              f"(entropy floor {data.entropy_floor:.4f})", flush=True)
    return metrics


if __name__ == "__main__":
    main()

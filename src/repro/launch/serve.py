"""Serving driver: compile-constant weights + continuous batching demo.

  python -m repro.launch.serve --arch smollm_360m --mode sparse_cfmm \
      --requests 6 --prompt-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import nn
from repro.launch.train import build_cfg
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--mode", default="int8",
                    choices=("dense", "int8", "cfmm", "sparse_cfmm"))
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = build_cfg(args.arch, args.preset)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, mode=args.mode,
                           sparsity=args.sparsity, batch_slots=args.slots,
                           max_seq=args.prompt_len + args.max_new + 8)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.randint(1, cfg.vocab,
                                            size=args.prompt_len)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.tokens_out) for r in reqs)
    for r in reqs[:3]:
        print(f"[serve] req {r.rid}: {len(r.tokens_out)} tokens "
              f"-> {r.tokens_out[:8]}...")
    print(f"[serve] mode={args.mode} {total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s, incl. compile)")
    return reqs


if __name__ == "__main__":
    main()

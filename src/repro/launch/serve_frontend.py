"""Replicated-pipeline serving driver — N Fig 7 chains behind one front
door.

  PYTHONPATH=src python -m repro.launch.serve_frontend \
      --replicas 2 --stages 2 --microbatch 2 --mode sparse_cfmm \
      --width 0.25 --hw 32

Carves disjoint per-replica device groups from the local device list
(fan a CPU host out with
XLA_FLAGS=--xla_force_host_platform_device_count=N), compiles the model
ONCE, places each replica's stage subtrees on its own group, and streams
a wave of requests through the shared admission queue with least-loaded
routing — reporting aggregate throughput, per-replica rows/bubble, queue
depth, and p50/p95 request latency.

Fault drill (--kill-replica R [--kill-step K]): after the healthy wave,
arm a fail-stop on replica R, rerun the same traffic, report the
watchdog/requeue recovery, then restart the replica and show the fleet
rebalanced.  Open loop (--open-loop FACTOR [--slo-rows N]): replay a
Poisson arrival plan at FACTOR x the fleet's measured row capacity, with
an optional p95 admission budget of N measured row-times — reports
goodput, shed fraction, and p50/p95 (DESIGN.md §10).

Telemetry (--trace out.json [--sparsity-groups G]): attach a
``repro.obs.Telemetry`` to the fleet and save the whole serve — request
admission/queue/dispatch/collect lifecycles, per-stage tick spans, idle
and edge markers — as Chrome trace-event JSON, loadable directly at
https://ui.perfetto.dev (DESIGN.md §11).  ``--sparsity-groups`` also
profiles post-ReLU activation sparsity and prints the per-layer summary.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import resnet
from repro.obs import Telemetry
from repro.serving.faults import Fault, FaultInjector
from repro.serving.frontend import FrontendRequest, ResNetFrontend
from repro.serving.loadgen import (offered_rows_per_s, poisson_plan,
                                   run_open_loop)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--mode", default="int8",
                    choices=("int8", "cfmm", "sparse_cfmm", "bitserial"))
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--stages", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rows", type=int, default=4,
                    help="images per request")
    ap.add_argument("--watchdog-ticks", type=int, default=8,
                    help="no-progress steps before a replica is failed")
    ap.add_argument("--kill-replica", type=int, default=None, metavar="R",
                    help="fault drill: fail-stop replica R mid-wave, "
                         "recover, restart")
    ap.add_argument("--kill-step", type=int, default=2,
                    help="engine step (after arming) at which the "
                         "fail-stop engages")
    ap.add_argument("--open-loop", type=float, default=None,
                    metavar="FACTOR",
                    help="Poisson open-loop wave at FACTOR x measured "
                         "capacity")
    ap.add_argument("--slo-rows", type=float, default=None, metavar="N",
                    help="p95 admission budget: N x measured per-row "
                         "time (open loop only; default: no shedding)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record the serve as Chrome trace-event JSON "
                         "(open in https://ui.perfetto.dev)")
    ap.add_argument("--sparsity-groups", type=int, default=None,
                    metavar="G",
                    help="profile post-ReLU activation sparsity per "
                         "G-channel coarse_in group (adds per-layer "
                         "zero-count outputs to the conv kernels)")
    args = ap.parse_args(argv)

    telemetry = None
    if args.trace is not None or args.sparsity_groups is not None:
        telemetry = Telemetry(trace=args.trace is not None,
                              sparsity_groups=args.sparsity_groups)

    cfg = resnet.ResNetConfig(width_mult=args.width, num_classes=100,
                              in_hw=args.hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    fe = ResNetFrontend(cfg, params, mode=args.mode,
                        sparsity=args.sparsity, n_replicas=args.replicas,
                        n_stages=args.stages, microbatch=args.microbatch,
                        watchdog_ticks=args.watchdog_ticks,
                        telemetry=telemetry)
    rng = np.random.RandomState(0)

    def wave():
        return [FrontendRequest(rid=i, images=rng.randn(
            args.rows, args.hw, args.hw, 3).astype(np.float32))
            for i in range(args.requests)]

    fe.run(wave())                             # warmup (compiles replicas)
    fe.reset_stats()
    reqs = wave()
    t0 = time.time()
    fe.run(reqs)
    dt = time.time() - t0
    st = fe.stats()
    n_img = args.requests * args.rows
    print(f"[frontend] {st['n_replicas']} replica(s) x "
          f"{st['replicas'][0]['n_stages']} stage(s), microbatch "
          f"{st['microbatch']}: {n_img} images / {args.requests} requests "
          f"in {dt:.2f}s ({n_img / dt:.1f} im/s wall)")
    print(f"  latency p50 {st['latency_p50_s'] * 1e3:.1f} ms | p95 "
          f"{st['latency_p95_s'] * 1e3:.1f} ms | max queue depth "
          f"{st['max_queue_depth']}")
    for r, rs in enumerate(st["replicas"]):
        print(f"  replica {r}: {st['rows_dispatched'][r]} rows / "
              f"{st['requests_dispatched'][r]} requests, bubble "
              f"{rs['bubble_fraction']:.2f}, devices {rs['stage_devices']}")

    if args.kill_replica is not None:
        inj = FaultInjector()
        inj.arm(fe.replicas[args.kill_replica],
                Fault("kill", at_step=args.kill_step))
        fe.reset_stats()
        reqs = wave()
        t0 = time.time()
        fe.run(reqs)
        dt = time.time() - t0
        st = fe.stats()
        done = sum(r.done for r in reqs)
        print(f"[faults] killed replica {args.kill_replica} at step "
              f"{args.kill_step}: {done}/{len(reqs)} requests completed "
              f"in {dt:.2f}s | replicas failed {st['replicas_failed']} | "
              f"{st['rows_requeued']} rows requeued over "
              f"{st['requeues']} spans")
        inj.disarm(fe.replicas[args.kill_replica])
        fe.restart_replica(args.kill_replica)
        fe.reset_stats()
        fe.run(wave())
        st = fe.stats()
        print(f"[faults] replica {args.kill_replica} restarted: "
              f"rows/replica {st['rows_dispatched']}, failures "
              f"{st['replicas_failed']}")

    if args.open_loop is not None:
        # warm the 1-row microbatch shape on every replica, then measure
        # the service rate on steady-state completions only
        fe.run([FrontendRequest(rid=-(r + 1),
                                images=rng.randn(1, args.hw, args.hw,
                                                 3).astype(np.float32))
                for r in range(args.replicas)])
        fe.reset_service_rate()
        fe.run(wave())
        st = fe.stats()
        cap = st["est_rows_per_s"]
        if args.slo_rows is not None:
            fe.slo_p95_s = args.slo_rows * st["est_row_time_s"]
        pool = rng.randn(8, args.hw, args.hw, 3).astype(np.float32)
        plan = poisson_plan(rate_rps=args.open_loop * cap / 1.25,
                            n_requests=args.requests, image_pool=pool,
                            size_mix=((1, 3.0), (2, 1.0)), seed=0,
                            rid_base=10_000)
        fe.reset_stats()
        res = run_open_loop(fe, plan)
        print(f"[open-loop] {args.open_loop:.1f}x capacity "
              f"({cap:.1f} rows/s): offered "
              f"{offered_rows_per_s(plan):.1f} rows/s | admitted "
              f"{res['admitted']}/{res['offered']} | shed "
              f"{res['rejected']} ({res['shed_fraction']:.0%}) | goodput "
              f"{res['goodput_rows_s']:.1f} rows/s | p50 "
              f"{res['latency_p50_s'] * 1e3:.1f} ms | p95 "
              f"{res['latency_p95_s'] * 1e3:.1f} ms")

    if telemetry is not None and telemetry.sparsity is not None:
        snap = telemetry.sparsity.snapshot()
        print(f"[sparsity] {snap['microbatches_profiled']} microbatches, "
              f"{len(snap['layers'])} layers, overall post-ReLU zero "
              f"fraction {snap['overall_zero_fraction']:.3f} "
              f"(groups of {snap['groups']})")
        worst = sorted(snap["layers"].items(),
                       key=lambda kv: -kv[1]["zero_fraction"])[:3]
        for name, lay in worst:
            print(f"  {name}: zero {lay['zero_fraction']:.3f}, all-zero "
                  f"{snap['groups']}-lane cells "
                  f"{max(lay['group_allzero_cell_fraction']):.3f} (max "
                  f"group)")
    if args.trace is not None:
        telemetry.trace.save(args.trace)
        n = len(telemetry.trace.spans) + len(telemetry.trace.instants)
        print(f"[trace] {n} events -> {args.trace} "
              f"(validate: python -m repro.obs.trace {args.trace}; "
              f"view: https://ui.perfetto.dev)")
    return fe


if __name__ == "__main__":
    main()

"""Replicated-pipeline serving driver — N Fig 7 chains behind one front
door.

  PYTHONPATH=src python -m repro.launch.serve_frontend \
      --replicas 2 --stages 2 --microbatch 2 --mode sparse_cfmm \
      --width 0.25 --hw 32

Carves disjoint per-replica device groups from the local device list
(fan a CPU host out with
XLA_FLAGS=--xla_force_host_platform_device_count=N), compiles the model
ONCE, places each replica's stage subtrees on its own group, and streams
a wave of requests through the shared admission queue with least-loaded
routing — reporting aggregate throughput, per-replica rows/bubble, queue
depth, and p50/p95 request latency.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import resnet
from repro.serving.frontend import FrontendRequest, ResNetFrontend


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--mode", default="int8",
                    choices=("int8", "cfmm", "sparse_cfmm", "bitserial"))
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--stages", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rows", type=int, default=4,
                    help="images per request")
    args = ap.parse_args(argv)

    cfg = resnet.ResNetConfig(width_mult=args.width, num_classes=100,
                              in_hw=args.hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    fe = ResNetFrontend(cfg, params, mode=args.mode,
                        sparsity=args.sparsity, n_replicas=args.replicas,
                        n_stages=args.stages, microbatch=args.microbatch)
    rng = np.random.RandomState(0)

    def wave():
        return [FrontendRequest(rid=i, images=rng.randn(
            args.rows, args.hw, args.hw, 3).astype(np.float32))
            for i in range(args.requests)]

    fe.run(wave())                             # warmup (compiles replicas)
    fe.reset_stats()
    reqs = wave()
    t0 = time.time()
    fe.run(reqs)
    dt = time.time() - t0
    st = fe.stats()
    n_img = args.requests * args.rows
    print(f"[frontend] {st['n_replicas']} replica(s) x "
          f"{st['replicas'][0]['n_stages']} stage(s), microbatch "
          f"{st['microbatch']}: {n_img} images / {args.requests} requests "
          f"in {dt:.2f}s ({n_img / dt:.1f} im/s wall)")
    print(f"  latency p50 {st['latency_p50_s'] * 1e3:.1f} ms | p95 "
          f"{st['latency_p95_s'] * 1e3:.1f} ms | max queue depth "
          f"{st['max_queue_depth']}")
    for r, rs in enumerate(st["replicas"]):
        print(f"  replica {r}: {st['rows_dispatched'][r]} rows / "
              f"{st['requests_dispatched'][r]} requests, bubble "
              f"{rs['bubble_fraction']:.2f}, devices {rs['stage_devices']}")
    return fe


if __name__ == "__main__":
    main()

"""Pipeline-parallel ResNet serving driver (the executable Fig 7).

  PYTHONPATH=src python -m repro.launch.serve_pipeline \
      --stages 4 --microbatch 2 --mode sparse_cfmm --width 0.25 --hw 32

Plans stages (MAC-balanced, or from the Fig 7 chip packing with
--from-partition), places each stage's constant weights on its own local
device (fan a CPU host out with
XLA_FLAGS=--xla_force_host_platform_device_count=N), and streams
microbatched requests through the rotating schedule.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import partition
from repro.launch.mesh import pipeline_stage_devices
from repro.models import resnet
from repro.serving.pipeline import PipelineEngine, PipelineRequest


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--hw", type=int, default=32)
    ap.add_argument("--mode", default="int8",
                    choices=("int8", "cfmm", "sparse_cfmm", "bitserial"))
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--images", type=int, default=16)
    ap.add_argument("--from-partition", action="store_true",
                    help="stage map from the Fig 7 chip packing "
                         "(re-balanced to --stages) instead of MACs")
    args = ap.parse_args(argv)

    cfg = resnet.ResNetConfig(width_mult=args.width, num_classes=100,
                              in_hw=args.hw)
    params = resnet.init(jax.random.PRNGKey(0), cfg)
    plan = None
    if args.from_partition:
        blocks = resnet.conv_blocks_for(cfg)
        plan = partition.solve_max_throughput(blocks).stage_plans(
            blocks, args.stages)
    devices = pipeline_stage_devices(args.stages)
    engine = PipelineEngine(cfg, params, mode=args.mode,
                            sparsity=args.sparsity, n_stages=args.stages,
                            plan=plan, microbatch=args.microbatch,
                            devices=devices)
    rng = np.random.RandomState(0)
    reqs = [PipelineRequest(rid=i, images=rng.randn(
        args.images // 2, args.hw, args.hw, 3).astype(np.float32))
            for i in range(2)]
    engine.run(reqs)                       # warmup (compiles every stage)
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    while engine.step():
        pass
    dt = time.time() - t0
    st = engine.stats()
    n_img = sum(len(r.images) for r in reqs)
    print(f"[pipeline] {st['n_stages']} stages on "
          f"{len(set(st['stage_devices']))} devices, microbatch "
          f"{st['microbatch']}: {n_img} images in {dt:.2f}s "
          f"({n_img / dt:.1f} im/s wall), bubble "
          f"{st['bubble_fraction']:.2f}")
    for s, blocks_ in enumerate(st["stage_blocks"]):
        w = st["stage_weight_bytes"][s]
        print(f"  stage {s}: blocks {blocks_[0]}..{blocks_[-1]} "
              f"({w / 1e3:.0f} kB resident) on {st['stage_devices'][s]}")
    for e, b in enumerate(st["edge_bytes"]):
        print(f"  edge {e}->{e + 1}: {b['int8_bytes']} B int8 / microbatch "
              f"(+{b['meta_bytes']} B scale), planned "
              f"{st['planned_link_bytes'][e] * st['microbatch']} B")
    return engine


if __name__ == "__main__":
    main()

"""Deterministic, checkpointable, host-sharded synthetic data pipeline.

Every batch is a pure function of (seed, step, host shard), so a restart
from checkpoint step N reproduces the exact token stream — the property
a 1000-node run needs so data order survives preemptions, and different
host counts partition (not duplicate) the stream.

Two sources:
  * ``random``: uniform tokens (for shape/throughput work);
  * ``markov``: an order-1 Markov chain with a seed-fixed sparse
    transition table — learnable structure, so example training runs show
    a real CE drop toward the chain's entropy floor.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "markov"       # markov | random
    branching: int = 4           # markov out-degree


class SyntheticDataset:
    """Stateless per-step batch generator (state == the step integer)."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // process_count
        self.process_index = process_index
        if cfg.source == "markov":
            rng = np.random.RandomState(cfg.seed)
            # each token can be followed by `branching` successors
            self.successors = rng.randint(
                0, cfg.vocab, size=(cfg.vocab, cfg.branching)).astype(np.int32)

    @property
    def entropy_floor(self) -> float:
        """CE floor in nats for the markov source."""
        return float(np.log(self.cfg.branching))

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.RandomState(
            (c.seed * 1_000_003 + step * 997 + self.process_index) % 2**31)
        if c.source == "random":
            toks = rng.randint(0, c.vocab, size=(self.local_batch, c.seq_len))
        else:
            toks = np.empty((self.local_batch, c.seq_len), np.int32)
            toks[:, 0] = rng.randint(0, c.vocab, size=self.local_batch)
            choices = rng.randint(0, c.branching,
                                  size=(self.local_batch, c.seq_len - 1))
            for t in range(1, c.seq_len):
                toks[:, t] = self.successors[toks[:, t - 1], choices[:, t - 1]]
        toks = toks.astype(np.int32)
        return {"tokens": toks, "labels": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1

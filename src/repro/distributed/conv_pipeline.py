"""Multi-device pipeline-parallel conv execution — the executable Fig 7.

The paper's multi-chip deployment is a *layer pipeline*: each chip holds
one contiguous slice of the network as constant parameters (persistent
weights), 8-bit feature maps cross the chip boundaries, and every chip
processes a different image at once (HPIPE's layer-pipelined discipline).
This module is the TPU/CPU-device analogue:

* each ``PipelineStage`` owns a *device-resident, disjoint* subtree of the
  compiled parameters (only its own units' constant weights — the
  "persistent" property, spy-tested in tests/test_pipeline.py) and one
  jitted stage program;
* edges carry the quantization-domain pair ``(int8 activations, f32
  scale)`` — the 8-bit inter-chip link.  Per-edge payload bytes are
  *measured* from the arrays actually transferred and cross-checked
  against ``partition.StagePlan.link_bytes``;
* microbatches rotate through the stages on a GPipe-style fill/steady/
  drain schedule (``tick``): at every tick each stage that holds an input
  launches its program and hands the output to its successor's inlet
  buffer.  Stages are visited in reverse order, so stage ``s``'s launch
  for microbatch ``m`` and the transfer of microbatch ``m+1`` into its
  inlet are both in flight in the same tick — the double-buffered stage
  boundary of paper SS II-D.1.  JAX's async dispatch overlaps the
  per-device launches; nothing here blocks until the caller consumes an
  output.

Why not a ``shard_map``/``ppermute`` collective: ResNet stages have
*heterogeneous* edge shapes (56x56x256 -> 7x7x2048), and a rotating
collective needs one uniform carrier buffer padded to the largest edge —
8-bit links exist precisely to keep boundary traffic small, so we keep
the native shapes and explicit per-edge transfers (DESIGN.md §7).

Bubble accounting: a schedule of M microbatches over S stages runs
``M + S - 1`` ticks -> bubble fraction ``(S-1)/(M+S-1)`` of stage-ticks
idle, measured and reported alongside the analytic value.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PipelineStage:
    """One device's slice of the network: jitted program + resident params."""

    index: int
    device: object
    fn: object                 # jitted (stage_params, carry) -> carry
    params: object             # device-resident param subtree (disjoint)
    unit_names: tuple

    def weight_bytes(self) -> int:
        return int(sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(self.params)))


def carry_bytes(carry) -> dict:
    """Measured payload of one edge transfer: int8 feature-map bytes vs
    everything else (the f32 scale scalar)."""
    int8_b = meta_b = 0
    for leaf in jax.tree.leaves(carry):
        if leaf.dtype == jnp.int8:
            int8_b += leaf.nbytes
        else:
            meta_b += leaf.nbytes
    return {"int8_bytes": int(int8_b), "meta_bytes": int(meta_b)}


class ConvPipeline:
    """Rotating-microbatch schedule over per-device pipeline stages.

    ``tick(inject=None, tag=None)`` advances every stage by one
    microbatch slot and returns the ``(tag, output)`` pairs that left the
    last stage this tick; ``serving.pipeline.PipelineEngine`` drives the
    fill/steady/drain loop and consumes ``stats()``.
    """

    def __init__(self, stages: list, replica: int = 0):
        self.stages = stages
        self.replica = replica          # which fleet replica owns this chain
        self.n_stages = len(stages)
        self._inlet = [None] * self.n_stages    # per-stage input buffer
        self._tags = [None] * self.n_stages
        self.ticks = 0
        self.microbatches_done = 0
        self.edge_bytes: list = [None] * max(self.n_stages - 1, 0)
        self.sample_inputs: list = [None] * self.n_stages

    @property
    def busy(self) -> bool:
        return any(b is not None for b in self._inlet)

    def tick(self, inject=None, tag=None) -> list:
        """One schedule step.  ``inject`` (optional) enters stage 0's
        inlet and is computed this tick; returns completed ``(tag, out)``
        pairs (possibly empty during fill).  Raises if stage 0 is still
        busy — callers gate injection on ``inlet_free``.  M microbatches
        over S stages complete in exactly M + S - 1 ticks."""
        done = []
        self.ticks += 1
        if inject is not None:
            assert self._inlet[0] is None, "stage 0 inlet busy"
            self._inlet[0] = jax.device_put(inject, self.stages[0].device)
            self._tags[0] = tag
        # reverse stage order: stage s launches on the microbatch its
        # inlet buffered, then frees the inlet for the predecessor's
        # output issued later in this same tick — stage s's compute and
        # the transfer into its inlet are concurrently in flight (the
        # double-buffered boundary; JAX dispatch is async)
        for s in reversed(range(self.n_stages)):
            if self._inlet[s] is None:
                continue
            stage = self.stages[s]
            carry, t = self._inlet[s], self._tags[s]
            if self.sample_inputs[s] is None:
                self.sample_inputs[s] = carry
            self._inlet[s] = None
            out = stage.fn(stage.params, carry)
            if s + 1 < self.n_stages:
                if self.edge_bytes[s] is None:
                    self.edge_bytes[s] = carry_bytes(out)
                out = jax.device_put(out, self.stages[s + 1].device)
                self._inlet[s + 1], self._tags[s + 1] = out, t
            else:
                self.microbatches_done += 1
                done.append((t, out))
        return done

    @property
    def inlet_free(self) -> bool:
        return self._inlet[0] is None

    @property
    def inlet_occupancy(self) -> tuple:
        """Which stage inlets hold a buffered microbatch — a microbatch
        advancing one stage flips two cells, so any healthy busy tick
        changes this pattern.  Part of the progress marker the serving
        front-end's per-replica watchdog hashes (DESIGN.md §10)."""
        return tuple(b is not None for b in self._inlet)

    def cancel_in_flight(self) -> list:
        """Drop every buffered microbatch and return their tags (the
        per-row segment lists the engine injected) so the caller can
        requeue the rows elsewhere — the drain half of replica failure
        recovery.  Cancelled microbatches never reach
        ``microbatches_done``; the chain is idle afterwards."""
        tags = []
        for s in range(self.n_stages):
            if self._inlet[s] is not None and self._tags[s] is not None:
                tags.append(self._tags[s])
            self._inlet[s] = None
            self._tags[s] = None
        return tags

    def reset_counters(self):
        """Zero the schedule counters (ticks, microbatches done — the
        bubble-fraction basis) so the next wave's stats stand alone;
        only legal while idle, since mid-flight microbatches would
        straddle the accounting boundary."""
        assert not self.busy, "reset_counters with microbatches in flight"
        self.ticks = 0
        self.microbatches_done = 0

    @property
    def in_flight(self) -> int:
        """Microbatches currently buffered in stage inlets — the chain's
        occupancy (a full chain holds ``n_stages``; 0 means idle),
        surfaced in ``stats()``.  The serving front-end's least-loaded
        router uses the row-granular ``PipelineEngine.pending_rows``
        instead, which counts partial microbatches at their real size;
        ``inlet_free`` gates injection."""
        return sum(b is not None for b in self._inlet)

    def stats(self) -> dict:
        s, m = self.n_stages, self.microbatches_done
        total = s * self.ticks
        return {
            "replica": self.replica,
            "n_stages": s,
            "in_flight": self.in_flight,
            "microbatches": m,
            "ticks": self.ticks,
            "bubble_fraction": 1.0 - (s * m) / total if total else 0.0,
            "bubble_fraction_analytic": (s - 1) / (m + s - 1) if m else 0.0,
            "edge_bytes": list(self.edge_bytes),
            "stage_weight_bytes": [st.weight_bytes() for st in self.stages],
            "stage_devices": [str(st.device) for st in self.stages],
        }

"""Multi-device pipeline-parallel conv execution — the executable Fig 7.

The paper's multi-chip deployment is a *layer pipeline*: each chip holds
one contiguous slice of the network as constant parameters (persistent
weights), 8-bit feature maps cross the chip boundaries, and every chip
processes a different image at once (HPIPE's layer-pipelined discipline).
This module is the TPU/CPU-device analogue:

* each ``PipelineStage`` owns a *device-resident, disjoint* subtree of the
  compiled parameters (only its own units' constant weights — the
  "persistent" property, spy-tested in tests/test_pipeline.py) and one
  jitted stage program;
* edges carry the quantization-domain pair ``(int8 activations, f32
  scale)`` — the 8-bit inter-chip link.  Per-edge payload bytes are
  *measured* from the arrays actually transferred and cross-checked
  against ``partition.StagePlan.link_bytes``;
* microbatches rotate through the stages on a GPipe-style fill/steady/
  drain schedule (``tick``): at every tick each stage that holds an input
  launches its program and hands the output to its successor's inlet
  buffer.  Stages are visited in reverse order, so stage ``s``'s launch
  for microbatch ``m`` and the transfer of microbatch ``m+1`` into its
  inlet are both in flight in the same tick — the double-buffered stage
  boundary of paper SS II-D.1.  JAX's async dispatch overlaps the
  per-device launches; nothing here blocks until the caller consumes an
  output.

Why not a ``shard_map``/``ppermute`` collective: ResNet stages have
*heterogeneous* edge shapes (56x56x256 -> 7x7x2048), and a rotating
collective needs one uniform carrier buffer padded to the largest edge —
8-bit links exist precisely to keep boundary traffic small, so we keep
the native shapes and explicit per-edge transfers (DESIGN.md §7).

Bubble accounting: a schedule of M microbatches over S stages runs
``M + S - 1`` ticks -> bubble fraction ``(S-1)/(M+S-1)`` of stage-ticks
idle, measured and reported alongside the analytic value.  Every idle
stage-tick is additionally *attributed* to exactly one cause
(DESIGN.md §11) — ``fill`` (work exists upstream but has never reached
this stage since the pipe was last empty), ``starved`` (the stage ran
before but its inlet is empty while work is still upstream: an
injection gap), ``drain`` (nothing upstream will ever arrive), or
``host`` (stage 0 idle while the front door holds undispatched rows —
the dispatch gap is on the host, not the schedule) — so the per-cause
counts sum to ``S·ticks − launches`` and hence to
``bubble_fraction · S · ticks`` by construction.

Counters live in a ``repro.obs.metrics.MetricsRegistry`` (the engine
shares its own registry down); ``ticks``/``microbatches_done`` remain
readable as properties so existing callers and tests see the same
surface.  With a ``repro.obs.Telemetry`` attached, each busy stage-tick
records a span (pid ``1 + replica``, tid = stage) covering the host-side
launch window, idle stage-ticks and edge transfers become instant
events, and profiled stage programs' sparsity aux feeds
``telemetry.sparsity``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import MetricsRegistry

# every idle stage-tick gets exactly one of these (DESIGN.md §11)
BUBBLE_CAUSES = ("fill", "starved", "drain", "host")


@dataclasses.dataclass
class PipelineStage:
    """One device's slice of the network: jitted program + resident params."""

    index: int
    device: object
    fn: object                 # jitted (stage_params, carry) -> carry
    params: object             # device-resident param subtree (disjoint)
    unit_names: tuple

    def weight_bytes(self) -> int:
        return int(sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(self.params)))


def carry_bytes(carry) -> dict:
    """Measured payload of one edge transfer: int8 feature-map bytes vs
    everything else (the f32 scale scalar)."""
    int8_b = meta_b = 0
    for leaf in jax.tree.leaves(carry):
        if leaf.dtype == jnp.int8:
            int8_b += leaf.nbytes
        else:
            meta_b += leaf.nbytes
    return {"int8_bytes": int(int8_b), "meta_bytes": int(meta_b)}


class ConvPipeline:
    """Rotating-microbatch schedule over per-device pipeline stages.

    ``tick(inject=None, tag=None)`` advances every stage by one
    microbatch slot and returns the ``(tag, output)`` pairs that left the
    last stage this tick; ``serving.pipeline.PipelineEngine`` drives the
    fill/steady/drain loop and consumes ``stats()``.
    """

    def __init__(self, stages: list, replica: int = 0, metrics=None,
                 telemetry=None):
        self.stages = stages
        self.replica = replica          # which fleet replica owns this chain
        self.n_stages = len(stages)
        self._inlet = [None] * self.n_stages    # per-stage input buffer
        self._tags = [None] * self.n_stages
        self.edge_bytes: list = [None] * max(self.n_stages - 1, 0)
        self.sample_inputs: list = [None] * self.n_stages
        # schedule counters live in the registry (shared with the owning
        # engine when it passes its own); direct references keep the hot
        # path at one attribute add per event
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._ticks = m.counter("pipe.ticks")
        self._mb_done = m.counter("pipe.microbatches_done")
        self._launches = [m.counter(f"pipe.stage{s}.launches")
                          for s in range(self.n_stages)]
        self._idle = {c: [m.counter(f"pipe.stage{s}.idle.{c}")
                          for s in range(self.n_stages)]
                      for c in BUBBLE_CAUSES}
        # attribution state: has stage s launched since the pipe was last
        # empty?  (distinguishes fill from starved)
        self._seen = [False] * self.n_stages
        # host-dispatch-gap hint: rows the front door holds undispatched
        # (the owning engine refreshes this every step; 0 standalone)
        self.door_rows = 0
        self.telemetry = telemetry
        self._profiled = bool(telemetry is not None and telemetry.profiled)
        tr = telemetry.trace if telemetry is not None else None
        if tr is not None:
            pid = 1 + replica
            tr.name_process(pid, f"replica {replica}")
            for s in range(self.n_stages):
                tr.name_thread(pid, s, f"stage {s}")

    @property
    def ticks(self) -> int:
        return self._ticks.value

    @property
    def microbatches_done(self) -> int:
        return self._mb_done.value

    @property
    def busy(self) -> bool:
        return any(b is not None for b in self._inlet)

    @staticmethod
    def _tag_args(tag) -> dict:
        """Span args from an engine segment tag (best-effort: direct
        ``ConvPipeline`` users may pass arbitrary tags)."""
        try:
            return {"rids": [req.rid for req, _, _ in tag],
                    "rows": sum(n for _, _, n in tag)}
        except (TypeError, ValueError, AttributeError):
            return {}

    def tick(self, inject=None, tag=None) -> list:
        """One schedule step.  ``inject`` (optional) enters stage 0's
        inlet and is computed this tick; returns completed ``(tag, out)``
        pairs (possibly empty during fill).  Raises if stage 0 is still
        busy — callers gate injection on ``inlet_free``.  M microbatches
        over S stages complete in exactly M + S - 1 ticks."""
        done = []
        self._ticks.inc()
        tel = self.telemetry
        tr = tel.trace if tel is not None else None
        pid = 1 + self.replica
        if inject is not None:
            assert self._inlet[0] is None, "stage 0 inlet busy"
            self._inlet[0] = jax.device_put(inject, self.stages[0].device)
            self._tags[0] = tag
        # bubble attribution over the post-injection occupancy: every
        # stage-tick is either a launch or gets exactly ONE idle cause,
        # so per-cause counts sum to S·ticks − launches — the measured
        # bubble_fraction's numerator — by construction (tested)
        occ = [b is not None for b in self._inlet]
        for s, busy_s in enumerate(occ):
            if busy_s:
                self._launches[s].inc()
                self._seen[s] = True
                continue
            if not any(occ[:s]):
                cause = ("host" if s == 0 and self.door_rows > 0
                         else "drain")
            else:
                cause = "starved" if self._seen[s] else "fill"
            self._idle[cause][s].inc()
            if tr is not None:
                tr.instant("idle", "pipeline", pid, s, cause=cause,
                           tick=self._ticks.value)
        # reverse stage order: stage s launches on the microbatch its
        # inlet buffered, then frees the inlet for the predecessor's
        # output issued later in this same tick — stage s's compute and
        # the transfer into its inlet are concurrently in flight (the
        # double-buffered boundary; JAX dispatch is async)
        for s in reversed(range(self.n_stages)):
            if self._inlet[s] is None:
                continue
            stage = self.stages[s]
            carry, t = self._inlet[s], self._tags[s]
            if self.sample_inputs[s] is None:
                self.sample_inputs[s] = carry
            self._inlet[s] = None
            t_begin = tr.now() if tr is not None else 0.0
            out = stage.fn(stage.params, carry)
            if self._profiled:
                out, aux = out
                tel.sparsity.add(aux, count_microbatch=(s == 0))
            if tr is not None:
                # the span covers the host-side launch window (JAX
                # dispatch is async; blocking for device time here would
                # serialize the very overlap the pipe exists for)
                tr.span(f"stage{s}", "pipeline", pid, s, t_begin,
                        tr.now(), tick=self._ticks.value,
                        **self._tag_args(t))
            if s + 1 < self.n_stages:
                if self.edge_bytes[s] is None:
                    self.edge_bytes[s] = carry_bytes(out)
                out = jax.device_put(out, self.stages[s + 1].device)
                self._inlet[s + 1], self._tags[s + 1] = out, t
                if tr is not None:
                    tr.instant("edge", "pipeline", pid, s, edge=s,
                               **self.edge_bytes[s])
            else:
                self._mb_done.inc()
                done.append((t, out))
        if not self.busy:
            # pipe drained: the next wave's early idle stage-ticks are
            # fill again, not starvation
            self._seen = [False] * self.n_stages
        return done

    @property
    def inlet_free(self) -> bool:
        return self._inlet[0] is None

    @property
    def inlet_occupancy(self) -> tuple:
        """Which stage inlets hold a buffered microbatch — a microbatch
        advancing one stage flips two cells, so any healthy busy tick
        changes this pattern.  Part of the progress marker the serving
        front-end's per-replica watchdog hashes (DESIGN.md §10)."""
        return tuple(b is not None for b in self._inlet)

    def cancel_in_flight(self) -> list:
        """Drop every buffered microbatch and return their tags (the
        per-row segment lists the engine injected) so the caller can
        requeue the rows elsewhere — the drain half of replica failure
        recovery.  Cancelled microbatches never reach
        ``microbatches_done``; the chain is idle afterwards."""
        tags = []
        for s in range(self.n_stages):
            if self._inlet[s] is not None and self._tags[s] is not None:
                tags.append(self._tags[s])
            self._inlet[s] = None
            self._tags[s] = None
        self._seen = [False] * self.n_stages
        return tags

    def reset_counters(self):
        """Zero the schedule counters (ticks, microbatches done, launch
        and bubble-attribution tallies — the bubble-fraction basis) so
        the next wave's stats stand alone; only legal while idle, since
        mid-flight microbatches would straddle the accounting boundary."""
        assert not self.busy, "reset_counters with microbatches in flight"
        self._ticks.reset()
        self._mb_done.reset()
        for c in self._launches:
            c.reset()
        for per_stage in self._idle.values():
            for c in per_stage:
                c.reset()
        self._seen = [False] * self.n_stages

    @property
    def in_flight(self) -> int:
        """Microbatches currently buffered in stage inlets — the chain's
        occupancy (a full chain holds ``n_stages``; 0 means idle),
        surfaced in ``stats()``.  The serving front-end's least-loaded
        router uses the row-granular ``PipelineEngine.pending_rows``
        instead, which counts partial microbatches at their real size;
        ``inlet_free`` gates injection."""
        return sum(b is not None for b in self._inlet)

    def stats(self) -> dict:
        s, m = self.n_stages, self.microbatches_done
        total = s * self.ticks
        launches = [c.value for c in self._launches]
        return {
            "replica": self.replica,
            "n_stages": s,
            "in_flight": self.in_flight,
            "microbatches": m,
            "ticks": self.ticks,
            "bubble_fraction": 1.0 - (s * m) / total if total else 0.0,
            "bubble_fraction_analytic": (s - 1) / (m + s - 1) if m else 0.0,
            # which stage, which cause, for every idle stage-tick: the
            # per-cause counts sum to S·ticks − Σlaunches exactly
            "stage_launches": launches,
            "bubble_attribution": {
                cause: [c.value for c in per_stage]
                for cause, per_stage in self._idle.items()},
            "idle_stage_ticks": total - sum(launches),
            "edge_bytes": list(self.edge_bytes),
            "stage_weight_bytes": [st.weight_bytes() for st in self.stages],
            "stage_devices": [str(st.device) for st in self.stages],
        }

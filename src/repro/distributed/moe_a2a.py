"""Explicit MoE dispatch/combine over all_to_all (shard_map).

SSPerf jamba it-2 showed XLA's auto-SPMD partitioner cannot recover the
token->expert all-to-all from scatter-based dispatch (it falls back to
replicating + all-reduce).  This module is the manual-collective path:
inside shard_map, every device bins its local tokens by target expert
*shard*, all_to_all's the bins across the expert-parallel axis, runs its
local experts, and all_to_all's results back.

The primitive works on one expert-parallel axis; the data axis stays
outside (each data row performs its own independent exchange).  Capacity
is per (source device x target shard), so buffer shapes are static.

Exactness: matches the scatter-based moe dispatch for tokens within
capacity (tests/test_moe_a2a.py runs both on a real 2x2 host-device mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def a2a_expert_exchange(x, expert_idx, gates, experts_apply, n_experts: int,
                        mesh: Mesh, ep_axis: str = "model",
                        dp_axis: str = "data", capacity_factor: float = 2.0):
    """MoE forward with explicit all_to_all dispatch.

    x: (T, d) tokens (sharded over dp and ep axes' product outside);
    expert_idx: (T, K) int32; gates: (T, K) f32;
    experts_apply(shard_index, x_e) -> y_e applies the LOCAL expert stack
    (E/ep experts) to (E_loc, cap_total, d).

    Returns (T, d) combined output, same sharding as x.
    """
    ep = mesh.shape[ep_axis]
    E_loc = n_experts // ep
    T, d = x.shape
    K = expert_idx.shape[1]
    T_loc = T // (mesh.shape[dp_axis] * ep)
    cap = int(max(8, round(T_loc * K / n_experts * capacity_factor
                           * E_loc)))
    cap = ((cap + 7) // 8) * 8

    def local_fn(x_l, idx_l, gates_l):
        # x_l: (T_loc, d); idx_l/gates_l: (T_loc, K)
        tl = x_l.shape[0]
        shard_of = idx_l // E_loc                           # (T_loc, K)
        within = idx_l % E_loc
        flat_shard = shard_of.reshape(-1)
        flat_within = within.reshape(-1)
        tok = jnp.repeat(jnp.arange(tl), K)
        # slot of each (token, choice) within its target shard's bin
        onehot = jax.nn.one_hot(flat_shard, ep, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        slot = jnp.take_along_axis(pos, flat_shard[:, None], axis=1)[:, 0]
        keep = slot < cap
        slot_c = jnp.where(keep, slot, cap)
        # send buffers: tokens + (expert-within, validity) sideband
        send_x = jnp.zeros((ep, cap, d), x_l.dtype)
        send_x = send_x.at[flat_shard, slot_c].set(x_l[tok], mode="drop")
        send_m = jnp.full((ep, cap), -1, jnp.int32)
        send_m = send_m.at[flat_shard, slot_c].set(flat_within, mode="drop")
        recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
        recv_m = jax.lax.all_to_all(send_m, ep_axis, 0, 0, tiled=False)
        # recv_x: (ep, cap, d) tokens destined for MY local experts
        flat_rx = recv_x.reshape(ep * cap, d)
        flat_rm = recv_m.reshape(ep * cap)
        # bin received tokens by local expert.  Correctness-first dense
        # (E_loc, ep*cap, d) layout — each expert sees all received slots,
        # masked to its own; production kernels would keep the binned
        # layout (grouped GEMM) instead of the E_loc-fold broadcast.
        e_onehot = jax.nn.one_hot(jnp.where(flat_rm >= 0, flat_rm, E_loc),
                                  E_loc + 1, dtype=flat_rx.dtype)
        x_e = (e_onehot[:, :E_loc].T[:, :, None] *
               flat_rx[None, :, :])                          # (E_loc, S, d)
        y_e = experts_apply(x_e)                             # (E_loc, S, d)
        y_flat = jnp.einsum("te,etd->td", e_onehot[:, :E_loc], y_e)
        # return to senders
        back = jax.lax.all_to_all(y_flat.reshape(ep, cap, d), ep_axis,
                                  0, 0, tiled=False)
        # combine at the source: gather each kept choice, weight, sum
        out = jnp.zeros_like(x_l)
        gathered = back[flat_shard, slot_c.clip(0, cap - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0)
        w = gates_l.reshape(-1)[:, None].astype(gathered.dtype)
        out = out.at[tok].add(gathered * w)
        return out

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P((dp_axis, ep_axis)), P((dp_axis, ep_axis)),
                             P((dp_axis, ep_axis))),
                   out_specs=P((dp_axis, ep_axis)))
    return fn(x, expert_idx, gates)

"""Logical-axis sharding: rules tables, PartitionSpec construction with
divisibility guards, and in-graph sharding constraints.

Logical axes used across the codebase:
  batch/seq            activations
  embed/vocab/ffn_*    weight matrices (in x out)
  heads_q/heads_kv     attention projections (out dim = heads*head_dim)
  experts_stack        MoE expert-stacked leading dim
  layers               scan-stacked leading dim (never sharded)
  *_s                  state/cache axes (heads_kv_sharded etc.)

The rules map logical -> mesh axes.  A guard drops any assignment whose
dimension is not divisible by the mesh-axis size (e.g. smollm's 15 heads
on a 16-way model axis) — the dry run then shows the replication cost in
the roofline instead of failing to lower.
"""
from __future__ import annotations

import logging

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import nn

log = logging.getLogger(__name__)

# Training: FSDP over 'data' on the weight in-dim, TP over 'model' on the
# out-dim (Megatron column-parallel; down/o projections are row-parallel
# via their own in-axis entry).  'pod' stays pure-DP.
TRAIN_RULES = {
    "batch": ("data",),
    "seq": (),
    "embed": ("data",),          # FSDP shard of the d_model dim
    "embed_out": ("model",),
    "vocab": ("model",),
    "ffn_in": ("model",),        # column-parallel out-dim (gate/up)
    "ffn_out": ("model",),       # row-parallel in-dim (down proj)
    "heads_q": ("model",),
    "heads_kv": ("model",),
    "kv_lora": (),
    "mamba_inner": ("model",),
    "experts": ("model",),       # router out-dim
    "experts_stack": ("model",), # expert parallelism
    "conv_in": (),
    "conv_out": ("model",),
    "classes": ("model",),
    "heads_s": ("model",),
    "heads_kv_sharded": ("model",),
    "mamba_inner_s": ("model",),
    "embed_s": (),
    "layers": (),
}

# Serving: weights replicated across 'data' (each DP replica serves its own
# requests), TP over 'model'; caches shard batch over 'data'.
SERVE_RULES = dict(TRAIN_RULES)
SERVE_RULES.update({
    "embed": (),
    "ffn_out": (),
    "kv_seq": (),
})

# Perf variant (SSPerf it-1): small dense models on a fixed 16x16 mesh are
# strangled by TP activation all-reduces; fold 'model' into data parallel —
# batch shards over both axes, weights FSDP over both, no activation ARs.
DP_ONLY_TRAIN_RULES = dict(TRAIN_RULES)
DP_ONLY_TRAIN_RULES.update({
    "batch": (("data", "model"), "data"),
    "embed": (("data", "model"), "data"),
    "ffn_in": (),
    "ffn_out": (),
    "heads_q": (),
    "heads_kv": (),
    "mamba_inner": (),
    "experts_stack": ("model",),   # expert parallelism stays
    "vocab": (),
    "embed_out": (),
    "conv_out": (),
})

# Perf variant (SSPerf it-3): split-KV decode ("FlashDecoding on SPMD") —
# the KV cache seq dim shards over 'model'; XLA's partitioned softmax
# reductions emit small per-layer all-reduces instead of replicating the
# cache 16x.  Weight TP unchanged.
SERVE_SPLITKV_RULES = dict(SERVE_RULES)
SERVE_SPLITKV_RULES.update({
    "kv_seq": ("model",),
    "heads_kv_sharded": (),
})

# Perf variant (SSPerf jamba it-2): expert parallelism + pure DP — batch on
# 'data' only, experts sharded over 'model', NO tensor parallelism on the
# non-expert (mamba/attention/dense) linears.  Kills the per-layer TP
# activation all-reduces that dominate hybrid-MoE training; the only
# cross-'model' traffic left is the MoE dispatch/combine all-to-all.
EP_DP_TRAIN_RULES = dict(DP_ONLY_TRAIN_RULES)
EP_DP_TRAIN_RULES.update({
    "batch": ("data",),
    "embed": ("data",),
})

RULES_BY_NAME = {
    "train": TRAIN_RULES,
    "dp_only": DP_ONLY_TRAIN_RULES,
    "ep_dp": EP_DP_TRAIN_RULES,
    "serve": SERVE_RULES,
    "serve_splitkv": SERVE_SPLITKV_RULES,
}


def _axis_size(mesh: Mesh, mesh_ax) -> int:
    if isinstance(mesh_ax, tuple):
        size = 1
        for a in mesh_ax:
            size *= mesh.shape.get(a, 1)
        return size
    return mesh.shape.get(mesh_ax, 1)


def spec_for(axes: tuple, shape: tuple, rules: dict, mesh: Mesh) -> P:
    """Build a PartitionSpec, dropping non-divisible assignments and
    never using one mesh axis twice in a single spec.  Rule entries may be
    tuples of mesh axes (sharded over their product)."""
    entries = []
    used = set()
    for dim, ax in zip(shape, axes):
        assigned = None
        if ax is not None:
            for mesh_ax in rules.get(ax, ()):
                parts = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
                size = _axis_size(mesh, mesh_ax)
                if (size > 1 and not (used & set(parts))
                        and dim % size == 0):
                    assigned = mesh_ax
                    used.update(parts)
                    break
                elif size > 1:
                    log.debug("drop shard %s(%d) %% %s(%d)",
                              ax, dim, size, size)
        entries.append(assigned)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(params, mesh: Mesh, rules: dict = TRAIN_RULES):
    """Param tree (boxed or shape-structs in Params) -> NamedSharding tree."""
    def visit(p: nn.Param):
        return NamedSharding(mesh, spec_for(p.axes, p.value.shape, rules, mesh))
    return jax.tree.map(visit, params, is_leaf=lambda x: isinstance(x, nn.Param))


def shard(x, *axes):
    """In-graph sharding constraint by logical axes; no-op without a mesh."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    rules = _ACTIVE_RULES[0]
    spec = spec_for(tuple(axes), x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def get_abstract_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


_ACTIVE_RULES = [TRAIN_RULES]


class use_rules:
    def __init__(self, rules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.insert(0, self.rules)

    def __exit__(self, *a):
        _ACTIVE_RULES.pop(0)


def batch_shardings(batch_specs: dict, mesh: Mesh,
                    dp_axes=("pod", "data")) -> dict:
    """ShapeDtypeStruct batch dict -> NamedSharding dict (batch over the
    data-parallel axes; everything else replicated)."""
    dp = [ax for ax in dp_axes if mesh.shape.get(ax, 1) > 1]

    def visit(s):
        shape = s.shape
        # find the batch dim: first dim unless M-RoPE positions (3, B, ...)
        bdim = 0 if len(shape) < 2 or shape[0] != 3 else 1
        total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        entries = [None] * len(shape)
        if total > 1 and shape[bdim] % total == 0:
            entries[bdim] = tuple(dp) if len(dp) > 1 else dp[0]
        elif mesh.shape.get("data", 1) > 1 and shape[bdim] % mesh.shape["data"] == 0:
            entries[bdim] = "data"
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(visit, batch_specs)

"""Analytic roofline model (TPU-expected terms), cross-checked vs HLO.

The XLA *CPU* backend's cost_analysis counts while-loop (lax.scan) bodies
once and fuses very differently from the TPU backend, so HLO-derived
terms from the CPU dry-run under-count looped FLOPs/collectives.  This
module computes the three terms from first principles; EXPERIMENTS.md
reports analytic terms as primary with HLO terms alongside (agreement is
validated on unrolled lowerings for the hillclimb cells).

Strategy-aware: the ``rules_name`` argument mirrors
distributed.sharding.RULES_BY_NAME, so every SSPerf sharding variant has a
matching analytic prediction (hypothesis) and dry-run artifact (measure).

Conventions (per global step, then / chips for per-device):
  train  : FLOPs = 4 x forward (fwd + 2x bwd + 1x remat fwd); 3 x fwd
           without remat
  prefill: FLOPs = 2 N_active D + attn fwd
  decode : FLOPs = 2 N_active B + attn-vs-cache   (one token)
  weights traffic (serving): bytes/param = dense 2.0 | int8/cfmm ~1.0 |
           sparse_cfmm (1-s) + 1/8 ~ 0.33 at s=0.8
  collectives: ring factors (AR 2x buffer, AG/RS 1x buffer).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, SHAPES
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_BF16, PEAK_INT8, \
    Roofline

BYTES_PER_PARAM = {"dense": 2.0, "int8": 1.0, "cfmm": 1.0,
                   "bitserial": 1.0, "sparse_cfmm": 0.2 * 1.0 + 1.0 / 8}


def _linear_params(cfg: ArchConfig, n_params: int) -> float:
    """Matmul-bearing params ~ everything except the embedding table."""
    emb = cfg.vocab * cfg.d_model
    return max(n_params - emb, 1)


def _tp_shardable_fraction(cfg: ArchConfig, tp: int) -> float:
    """Fraction of linear-param volume whose TP-sharded dim divides ``tp``
    (the divisibility guard replicates the rest — e.g. smollm's 15x64
    attention projections on a 16-way axis)."""
    if tp <= 1:
        return 1.0
    d = cfg.d_model
    attn_ok = (cfg.n_heads * cfg.head_dim) % tp == 0 and \
        (cfg.n_kv_heads * cfg.head_dim) % tp == 0
    ffn_ok = cfg.d_ff % tp == 0
    attn_vol = 2 * d * cfg.n_heads * cfg.head_dim + \
        2 * d * cfg.n_kv_heads * cfg.head_dim
    ffn_vol = 3 * d * cfg.d_ff
    total = attn_vol + ffn_vol
    ok = (attn_vol if attn_ok else 0) + (ffn_vol if ffn_ok else 0)
    return ok / total


def _attn_fwd_flops(cfg: ArchConfig, B: int, S: int) -> float:
    """QK^T + AV flops, causal-halved, window-clipped, per forward."""
    total = 0.0
    for sig in cfg.layer_sigs():
        if sig["kind"] != "attn":
            continue
        span = S if sig["attn_type"] != "local" else min(cfg.window or S, S)
        eff = S * span if sig["attn_type"] == "local" else S * S / 2
        total += 4.0 * B * eff * cfg.n_heads * cfg.head_dim
    if cfg.encoder_decoder:
        total *= 2
    return total


def _attn_decode_flops(cfg: ArchConfig, B: int, S: int) -> float:
    total = 0.0
    for sig in cfg.layer_sigs():
        if sig["kind"] != "attn":
            continue
        span = S if sig["attn_type"] != "local" else min(cfg.window or S, S)
        total += 4.0 * B * span * cfg.n_heads * cfg.head_dim
    return total


def _kv_cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    """Decode-step KV/state read volume (global)."""
    total = 0.0
    for sig in cfg.layer_sigs():
        if sig["kind"] == "attn":
            if cfg.mla:
                total += B * S * (cfg.mla.kv_lora + cfg.mla.qk_rope) * 2
            else:
                span = S if sig["attn_type"] != "local" else \
                    min(cfg.window or S, S)
                total += 2 * B * span * cfg.n_kv_heads * cfg.head_dim * 2
        elif sig["kind"] == "mamba":
            total += B * cfg.ssm.d_inner * cfg.ssm.d_state * 4
        elif sig["kind"] == "rwkv":
            hd = cfg.ssm.head_dim
            total += B * (cfg.d_model // hd) * hd * hd * 4
    return total


def _kv_shard_ways(cfg: ArchConfig, B: int, dp: int, tp: int,
                   rules_name: str) -> float:
    """How many ways the KV cache actually shards under the rules."""
    ways = min(dp, B) if B % min(dp, B) == 0 else 1
    if rules_name == "serve_splitkv":
        return ways * tp          # seq dim shards over 'model'
    if cfg.mla:
        return ways               # latent cache has no heads dim
    if cfg.n_kv_heads % tp == 0:
        return ways * tp
    return ways                   # heads not divisible -> replicated


def _expert_params(cfg: ArchConfig) -> float:
    if cfg.moe is None:
        return 0.0
    n_moe = sum(1 for s in cfg.layer_sigs() if s["moe"])
    per = (3 if cfg.moe.gated else 2) * cfg.d_model * cfg.moe.d_ff_expert
    return n_moe * cfg.moe.n_experts * per


def _moe_a2a_bytes(cfg: ArchConfig, B, S, dp) -> float:
    """MoE dispatch+combine all-to-all wire bytes per device per forward."""
    if cfg.moe is None:
        return 0.0
    n_moe = sum(1 for s in cfg.layer_sigs() if s["moe"])
    tokens_local = B * S / max(dp, 1)
    return n_moe * 2 * tokens_local * cfg.d_model * 2


def _tp_ar_bytes(cfg, B_local, S, tp) -> float:
    """TP activation all-reduce wire bytes per device per forward:
    2 ARs/layer x ring 2x buffer."""
    if tp <= 1:
        return 0.0
    n_layers = cfg.n_layers + (cfg.n_enc_layers or 0)
    buf = B_local * S * cfg.d_model * 2
    return n_layers * 2 * 2.0 * buf


@dataclasses.dataclass
class CellModel:
    flops_device: float
    hbm_device: float
    wire_device: float
    breakdown: dict


def model_cell(cfg: ArchConfig, shape_name: str, mesh_shape: dict,
               n_params: int, n_active: int, serve_mode: str = "cfmm",
               rules_name: str | None = None, remat: bool = True) -> CellModel:
    sh = SHAPES[shape_name]
    B, S, step = sh["batch"], sh["seq"], sh["step"]
    chips = int(np.prod(list(mesh_shape.values())))
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    rules_name = rules_name or ("train" if step == "train" else "serve")
    if rules_name == "dp_only":
        dp, tp = dp * tp, 1
    elif rules_name == "ep_dp":
        tp = 1                    # no TP on non-expert linears
    B_local = max(B // min(dp, B), 1)
    n_lin = _linear_params(cfg, n_params)
    n_lin_active = _linear_params(cfg, n_active)

    if step == "train":
        D = B * S
        fwd = 2.0 * n_lin_active * D + _attn_fwd_flops(cfg, B, S)
        flops = (4.0 if remat else 3.0) * fwd
        # HBM: params ~3 reads bf16 + adam m/v rw f32 (16B) + master (8B);
        # activations ~12 boundary tensors per layer bf16.
        w_bytes = n_params * (3 * 2 + 16 + 8)
        act = 12.0 * cfg.n_layers * D * cfg.d_model * 2
        hbm = w_bytes + act
        # wire per device: FSDP all-gathers move only what this device
        # consumes — its TP/EP column slice when weights are 2D-sharded.
        e_params = _expert_params(cfg)
        ne_params = n_params - e_params
        tp0 = mesh_shape.get("model", 1)      # physical model-axis size
        if rules_name == "train":
            gathered = (ne_params + e_params) / tp0
        elif rules_name == "ep_dp" or rules_name == "dp_only":
            gathered = ne_params + e_params / tp0
        else:
            gathered = n_params
        fsdp = 3 * gathered * 2               # AG fwd + AG remat + RS grads
        pod = gathered * 2 if mesh_shape.get("pod", 1) > 1 else 0
        tp_ar = _tp_ar_bytes(cfg, B_local, S, tp) * 3
        a2a = _moe_a2a_bytes(cfg, B, S, dp if rules_name != "dp_only"
                             else dp) * 3
        wire = fsdp + pod + tp_ar + a2a
        return CellModel(flops / chips, hbm / chips, wire, dict(
            fwd_flops=fwd, attn_flops=_attn_fwd_flops(cfg, B, S),
            weight_bytes=w_bytes, act_bytes=act, fsdp_wire=fsdp,
            pod_wire=pod, tp_wire=tp_ar, a2a_wire=a2a, rules=rules_name))

    bpp = BYTES_PER_PARAM.get(serve_mode, 2.0)
    tp_frac = _tp_shardable_fraction(cfg, tp)
    w_shard = tp_frac * tp + (1 - tp_frac)          # effective shard ways

    if step == "prefill":
        D = B * S
        flops = 2.0 * n_lin_active * D + _attn_fwd_flops(cfg, B, S)
        w_dev = n_lin_active * bpp / w_shard
        act = 8.0 * cfg.n_layers * D * cfg.d_model * 2
        kv_write = _kv_cache_bytes(cfg, B, S)
        hbm_dev = w_dev + (act + kv_write) / chips
        wire = _tp_ar_bytes(cfg, B_local, S, tp)
        return CellModel(flops / chips, hbm_dev, wire, dict(
            weight_bytes_dev=w_dev, act_bytes=act, kv_bytes=kv_write,
            tp_wire=wire, rules=rules_name))

    # decode: one token
    flops = 2.0 * n_lin_active * B + _attn_decode_flops(cfg, B, S)
    kv_ways = _kv_shard_ways(cfg, B, dp, tp, rules_name)
    # compute replicates where KV replicates (same work on each shard)
    flops_dev = (2.0 * n_lin_active * B / min(chips, B * w_shard)
                 + _attn_decode_flops(cfg, B, S) / kv_ways)
    w_dev = n_lin_active * bpp / w_shard
    kv_dev = _kv_cache_bytes(cfg, B, S) / kv_ways
    hbm_dev = w_dev + kv_dev
    wire = _tp_ar_bytes(cfg, B_local, 1, tp)
    if rules_name == "serve_splitkv":
        n_attn = sum(1 for s_ in cfg.layer_sigs() if s_["kind"] == "attn")
        wire += n_attn * 2 * 2.0 * B_local * cfg.n_heads * \
            (cfg.head_dim + 2) * 4          # partial-softmax combines
    return CellModel(flops_dev, hbm_dev, wire, dict(
        weight_bytes_dev=w_dev, kv_bytes_dev=kv_dev, kv_shard_ways=kv_ways,
        tp_wire=wire, bytes_per_param=bpp, tp_shardable_frac=tp_frac,
        rules=rules_name))


def roofline_of(cfg: ArchConfig, shape_name: str, mesh_shape: dict,
                n_params: int, n_active: int, serve_mode="cfmm",
                model_flops: float = 0.0, rules_name: str | None = None,
                remat: bool = True) -> Roofline:
    m = model_cell(cfg, shape_name, mesh_shape, n_params, n_active,
                   serve_mode, rules_name, remat)
    chips = int(np.prod(list(mesh_shape.values())))
    return Roofline(m.flops_device, m.hbm_device, m.wire_device, chips,
                    model_flops)

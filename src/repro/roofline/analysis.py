"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = per-device HLO FLOPs / peak FLOP/s     (197 TF/s bf16)
  memory     = per-device HLO bytes  / HBM bandwidth  (819 GB/s)
  collective = per-device wire bytes / ICI bandwidth  (~50 GB/s/link)

compiled.cost_analysis() is the per-device (post-SPMD) program cost, so
no further division by chip count is needed; the spec's global form
HLO_FLOPs_global / (chips x peak) is identical.

Collective bytes are parsed from the optimized HLO text: for each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
we take the output buffer bytes and apply ring wire factors
(AR: 2(n-1)/n ~ 2x; AG/RS/A2A/CP: (n-1)/n ~ 1x).
"""
from __future__ import annotations

import dataclasses
import json
import re

# ---- TPU v5e hardware constants ----
PEAK_BF16 = 197e12        # FLOP/s per chip
PEAK_INT8 = 394e12        # OP/s per chip
HBM_BW = 819e9            # B/s per chip
ICI_BW = 50e9             # B/s per link (1 link assumed; 3D-torus upside noted)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<outs>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """-> {op_kind: {count, bytes, wire_bytes}} summed over instructions."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        b = _shape_bytes(m.group("outs"))
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += b
        rec["wire_bytes"] += b * _WIRE_FACTOR[op]
    return out


@dataclasses.dataclass
class Roofline:
    flops: float              # per-device
    hbm_bytes: float          # per-device
    wire_bytes: float         # per-device
    chips: int
    model_flops: float = 0.0  # 6*N*D (train) / 2*N_active*D (serve), global

    @property
    def compute_s(self):
        return self.flops / PEAK_BF16

    @property
    def memory_s(self):
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self):
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self):
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self):
        """MODEL_FLOPS / global HLO FLOPs — remat/redundancy waste."""
        if self.flops <= 0:
            return 0.0
        return self.model_flops / (self.flops * self.chips)

    @property
    def roofline_fraction(self):
        """Achievable MFU bound: useful compute time / bound time."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops / self.chips / PEAK_BF16
        return useful_s / self.bound_s

    def to_dict(self):
        return dict(
            flops_per_device=self.flops, hbm_bytes_per_device=self.hbm_bytes,
            wire_bytes_per_device=self.wire_bytes, chips=self.chips,
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, dominant=self.dominant,
            model_flops=self.model_flops,
            useful_flops_fraction=self.useful_flops_fraction,
            roofline_fraction=self.roofline_fraction,
        )


def from_compiled(compiled, chips: int, model_flops: float) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):   # older jax returns [dict]
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    if hbm == 0.0:
        hbm = sum(float(v) for k, v in ca.items()
                  if k.startswith("bytes accessed"))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = parse_collectives(hlo)
    wire = sum(v["wire_bytes"] for v in coll.values())
    return Roofline(flops, hbm, wire, chips, model_flops)


def model_flops_for(cfg, n_params: int, active_params: int, shape: dict,
                    step: str) -> float:
    """6*N*D train; 2*N_active*D forward-only (prefill/decode-step)."""
    if step == "train":
        tokens = shape["batch"] * shape["seq"]
        return 6.0 * active_params * tokens
    if step == "prefill":
        tokens = shape["batch"] * shape["seq"]
        return 2.0 * active_params * tokens
    tokens = shape["batch"] * 1  # one decode step
    return 2.0 * active_params * tokens


def count_params_from_shapes(params_shapes) -> int:
    import jax
    import numpy as np
    from repro import nn
    vals = jax.tree.leaves(nn.unbox(params_shapes))
    return int(sum(np.prod(v.shape) for v in vals))


def active_param_count(cfg, total: int) -> int:
    """Subtract un-routed expert weight for MoE archs (top-k + shared)."""
    if cfg.moe is None:
        return total
    import numpy as np
    m = cfg.moe
    sigs = cfg.layer_sigs()
    n_moe_layers = sum(1 for s in sigs if s["moe"])
    per_expert = 3 * cfg.d_model * m.d_ff_expert if m.gated else \
        2 * cfg.d_model * m.d_ff_expert
    all_experts = n_moe_layers * m.n_experts * per_expert
    used = n_moe_layers * m.top_k * per_expert
    return int(total - all_experts + used)

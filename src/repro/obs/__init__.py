"""``repro.obs`` — zero-dependency telemetry for the serving stack.

Three pillars (DESIGN.md §11):

* ``trace``   — request + stage-tick span tracing, Chrome trace-event
  export (Perfetto-loadable), schema validator.
* ``metrics`` — Counter/Gauge/Histogram/Reservoir registry with a
  wave/life scope split; component ``stats()`` dicts are thin views
  over ``MetricsRegistry.snapshot()``.
* ``sparsity``— post-ReLU activation zero-fraction profiling fed by the
  conv lowerings' epilogues.

``Telemetry`` is the bundle the serving stack threads through
(frontend → engine → pipeline → kernels).  It is **off by default**
(``telemetry=None`` everywhere): the instrumented code guards every
hook behind one ``is None`` check, so the off path costs a branch.
"""
from __future__ import annotations

import time

from repro.obs.metrics import (Counter, Gauge, HighWater, Histogram,
                               MetricsRegistry, Reservoir, percentile)
from repro.obs.sparsity import SparsityProfiler
from repro.obs.trace import Trace, validate_chrome_trace

__all__ = [
    "Counter", "Gauge", "HighWater", "Histogram", "MetricsRegistry",
    "Reservoir", "percentile", "SparsityProfiler", "Trace",
    "validate_chrome_trace", "Telemetry",
]


class Telemetry:
    """What a serving component receives when observability is on.

    ``trace``: ``True`` (fresh buffer), a ``Trace`` instance, or
    ``None``.  ``sparsity_groups``: ``coarse_in`` lane-group size to
    profile activation sparsity at (``None`` = profiling off — the
    model compiles its unprofiled stage programs).  ``clock`` must be
    the same callable the frontend schedules with, so spans and SLO
    arithmetic share a time axis.
    """

    def __init__(self, trace=None, sparsity_groups=None,
                 clock=time.perf_counter, trace_capacity=200_000):
        if trace is True:
            trace = Trace(capacity=trace_capacity, clock=clock)
        assert trace is None or isinstance(trace, Trace), trace
        self.trace = trace
        self.sparsity = (None if sparsity_groups is None
                         else SparsityProfiler(groups=sparsity_groups))
        self.clock = clock

    @property
    def profiled(self) -> bool:
        """True when stage programs must emit sparsity aux."""
        return self.sparsity is not None

"""Metrics registry — the one accounting surface behind the serving stack.

Every counter the fleet used to keep as an ad-hoc ``self._foo = 0``
attribute lives here instead: a component owns a ``MetricsRegistry``,
creates named metrics once at construction time, and keeps direct Python
references to them for the hot path (``ctr.inc()`` is one attribute add —
no dict lookup per event).  ``stats()`` methods become thin views over
``registry.snapshot()``.

Scopes.  A metric is either ``wave``-scoped (zeroed by ``reset_wave()``
between measurement waves — dispatch counts, latency reservoirs, shed
counters) or ``life``-scoped (survives resets — odometers like lifetime
rows completed, calibration gauges like the EWMA row time).  The scope
split IS the ``reset_stats`` audit the frontend needed: a wave counter
that outlives a reset is now a bug you can test for structurally
(``registry.wave_names()`` vs what ``snapshot()`` reports) instead of a
list you keep in your head.

Four metric kinds, all zero-dependency and O(1) per observation:

* ``Counter``   — monotonically increasing within a wave.
* ``Gauge``     — last-write-wins scalar; ``HighWater`` keeps the max.
* ``Histogram`` — fixed bucket bounds, percentile by linear
  interpolation inside the winning bucket.  Constant memory, any stream
  length; the right tool when the window must not be bounded.
* ``Reservoir`` — bounded sliding window of the newest N samples
  (deque), exact percentiles over the window via ``np.percentile``.
  This is the frontend's latency store: p50/p95 over the last
  ``latency_window`` requests.
"""
from __future__ import annotations

import collections
import math

import numpy as np

WAVE = "wave"
LIFE = "life"
_SCOPES = (WAVE, LIFE)


def percentile(xs, q):
    """``np.percentile`` with the serving stack's empty convention:
    ``None`` when there are no samples (a fleet that served nothing has
    no p95, not a p95 of 0)."""
    xs = list(xs)
    if not xs:
        return None
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


class _Metric:
    kind = "metric"

    def __init__(self, name: str, scope: str = WAVE, help: str = ""):
        assert scope in _SCOPES, scope
        self.name = name
        self.scope = scope
        self.help = help

    def reset(self):
        raise NotImplementedError

    def snapshot(self):
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name, scope=WAVE, help=""):
        super().__init__(name, scope, help)
        self.value = 0

    def inc(self, n=1):
        self.value += n

    def reset(self):
        self.value = 0

    def snapshot(self):
        return self.value


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name, scope=WAVE, help="", initial=0.0):
        super().__init__(name, scope, help)
        self._initial = initial
        self.value = initial

    def set(self, v):
        self.value = v

    def reset(self):
        self.value = self._initial

    def snapshot(self):
        return self.value


class HighWater(Gauge):
    """Gauge that remembers the maximum observed value (queue depth)."""

    kind = "highwater"

    def observe(self, v):
        if v > self.value:
            self.value = v


class Histogram(_Metric):
    """Fixed-bucket histogram: ``bounds`` are the inclusive upper edges
    of each bucket; one implicit overflow bucket catches the rest.
    ``percentile(q)`` interpolates linearly within the winning bucket —
    constant memory for unbounded streams, resolution set by the bucket
    grid (the classic prometheus trade)."""

    kind = "histogram"

    def __init__(self, name, bounds, scope=WAVE, help=""):
        super().__init__(name, scope, help)
        bounds = tuple(float(b) for b in bounds)
        assert bounds == tuple(sorted(bounds)) and len(bounds) >= 1, bounds
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)     # + overflow
        self.total = 0
        self.sum = 0.0
        self._lo = math.inf                       # for interpolation floors

    def observe(self, v):
        v = float(v)
        self.total += 1
        self.sum += v
        if v < self._lo:
            self._lo = v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def reset(self):
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0
        self.sum = 0.0
        self._lo = math.inf

    def percentile(self, q):
        """Linear interpolation inside the bucket holding the q-th
        sample; ``None`` on empty, clamped to the last finite bound for
        overflow hits."""
        if self.total == 0:
            return None
        rank = (q / 100.0) * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else min(self._lo, self.bounds[0])
            hi = self.bounds[i] if i < len(self.bounds) else self.bounds[-1]
            if seen + c >= rank:
                frac = (rank - seen) / c
                return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))
            seen += c
        return float(self.bounds[-1])

    def snapshot(self):
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "total": self.total, "sum": self.sum,
                "p50": self.percentile(50), "p95": self.percentile(95)}


class Reservoir(_Metric):
    """Bounded sliding window of the newest ``window`` samples, in
    arrival order (overflow evicts the oldest).  Exact percentiles over
    the window; ``observed`` counts everything ever seen.  Supports
    ``len()`` and iteration so existing code that treated the latency
    store as a plain deque keeps working."""

    kind = "reservoir"

    def __init__(self, name, window, scope=WAVE, help=""):
        super().__init__(name, scope, help)
        assert window >= 1, window
        self.window = window
        self._buf = collections.deque(maxlen=window)
        self.observed = 0

    def observe(self, v):
        self._buf.append(float(v))
        self.observed += 1

    append = observe                              # deque-compatible alias

    def reset(self):
        self._buf.clear()
        self.observed = 0

    def percentile(self, q):
        return percentile(self._buf, q)

    def values(self):
        return list(self._buf)

    def __len__(self):
        return len(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def snapshot(self):
        return {"window": self.window, "count": len(self._buf),
                "observed": self.observed,
                "p50": self.percentile(50), "p95": self.percentile(95)}


class MetricsRegistry:
    """Named metrics with get-or-create semantics and a wave/life scope
    split.  One registry per component (frontend, engine+pipe); nesting
    is done at snapshot time by the owner, not here."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name, *args, **kw):
        m = self._metrics.get(name)
        if m is not None:
            assert isinstance(m, cls), (name, type(m), cls)
            return m
        m = cls(name, *args, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name, scope=WAVE, help=""):
        return self._get_or_create(Counter, name, scope, help)

    def gauge(self, name, scope=WAVE, help="", initial=0.0):
        return self._get_or_create(Gauge, name, scope, help, initial)

    def highwater(self, name, scope=WAVE, help="", initial=0.0):
        return self._get_or_create(HighWater, name, scope, help, initial)

    def histogram(self, name, bounds, scope=WAVE, help=""):
        return self._get_or_create(Histogram, name, bounds, scope, help)

    def reservoir(self, name, window, scope=WAVE, help=""):
        return self._get_or_create(Reservoir, name, window, scope, help)

    def get(self, name):
        return self._metrics[name]

    def __contains__(self, name):
        return name in self._metrics

    def names(self):
        return sorted(self._metrics)

    def wave_names(self):
        return sorted(n for n, m in self._metrics.items() if m.scope == WAVE)

    def reset_wave(self):
        """Zero every wave-scoped metric; life-scoped metrics survive.
        THE reset between measurement waves — components must not keep
        wave counters outside the registry."""
        for m in self._metrics.values():
            if m.scope == WAVE:
                m.reset()

    def snapshot(self):
        """{name: value-or-dict} for every metric, wave and life."""
        return {n: self._metrics[n].snapshot() for n in sorted(self._metrics)}

"""Span tracing with Chrome trace-event export.

Every request that crosses the front door gets a lifecycle of spans —
``admission`` (validation + SLO check inside ``submit``), ``queue``
(admitted → first row routed), ``dispatch`` (first → last row handed to
a replica), ``collect`` (last dispatch → logits scattered back) — and
every pipeline tick gets one ``stage-tick`` span per busy stage plus
idle/edge markers.  Spans land in a bounded in-memory buffer and export
as Chrome trace-event JSON (``Trace.to_chrome_trace()``), loadable
directly in Perfetto / ``chrome://tracing``.

Design choices that keep this correct under load:

* **Completed spans only.**  The buffer stores spans at their *end*
  time, never open begin events.  A bounded buffer that dropped its
  oldest raw ``B``/``E`` events under pressure would orphan pairs and
  produce invalid traces; dropping whole completed spans keeps every
  export well-formed no matter how much history was evicted
  (``Trace.dropped`` counts what fell off).
* **Track layout.**  pid 0 is the front door (one tid per request id, so
  each request reads as its own Perfetto track); pid ``1 + r`` is
  replica ``r`` (one tid per pipeline stage).  ``B``/``E`` pairs are
  reconstructed per track at export time with an explicit stack, so
  pairs are matched by construction — the validator below re-checks
  anyway.
* **Clock.**  One injected ``clock()`` (default ``time.perf_counter``)
  shared with the frontend, so span timestamps and the scheduler's SLO
  arithmetic read the same axis.  Exported ``ts`` is microseconds since
  the trace epoch (clock at construction).

``python -m repro.obs.trace out.json`` validates a file against the
schema (required keys, monotonic ts, matched B/E pairs) — CI runs this
over the artifact it uploads.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import sys
import time

# phase types we emit / accept
_PH_ALLOWED = ("B", "E", "i", "I", "M", "X")


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed span: [ts, ts + dur] microseconds on track
    (pid, tid)."""

    name: str
    cat: str
    pid: int
    tid: int
    ts: float            # µs since trace epoch
    dur: float           # µs, >= 0
    args: dict


@dataclasses.dataclass(frozen=True)
class Instant:
    name: str
    cat: str
    pid: int
    tid: int
    ts: float
    args: dict


class Trace:
    """Bounded in-memory span buffer with Chrome trace-event export."""

    def __init__(self, capacity: int = 200_000, clock=time.perf_counter):
        assert capacity >= 1, capacity
        self.capacity = capacity
        self.clock = clock
        self.t0 = clock()
        self.spans: collections.deque[Span] = collections.deque(
            maxlen=capacity)
        self.instants: collections.deque[Instant] = collections.deque(
            maxlen=capacity)
        self.dropped = 0
        self._proc_names: dict[int, str] = {}
        self._thread_names: dict[tuple[int, int], str] = {}

    # -- recording ---------------------------------------------------

    def now(self) -> float:
        """Absolute clock seconds (same axis the serving stack stamps)."""
        return self.clock()

    def us(self, t_abs: float) -> float:
        """Absolute clock seconds -> µs since the trace epoch."""
        return (t_abs - self.t0) * 1e6

    def span(self, name, cat, pid, tid, t_begin, t_end, **args):
        """Record a completed span; ``t_begin``/``t_end`` are absolute
        clock seconds (negative durations are clamped to zero rather
        than corrupting the export)."""
        if len(self.spans) == self.spans.maxlen:
            self.dropped += 1
        ts = self.us(t_begin)
        self.spans.append(Span(name, cat, int(pid), int(tid), ts,
                               max(self.us(t_end) - ts, 0.0), args))

    def instant(self, name, cat, pid, tid, t=None, **args):
        if len(self.instants) == self.instants.maxlen:
            self.dropped += 1
        t = self.clock() if t is None else t
        self.instants.append(Instant(name, cat, int(pid), int(tid),
                                     self.us(t), args))

    def name_process(self, pid, name):
        self._proc_names[int(pid)] = name

    def name_thread(self, pid, tid, name):
        self._thread_names[(int(pid), int(tid))] = name

    # -- export ------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON object: ``{"traceEvents": [...]}``,
        events sorted by ts with matched B/E pairs per (pid, tid)."""
        meta = []
        for pid, name in sorted(self._proc_names.items()):
            meta.append({"name": "process_name", "ph": "M", "ts": 0.0,
                         "pid": pid, "tid": 0, "args": {"name": name}})
        for (pid, tid), name in sorted(self._thread_names.items()):
            meta.append({"name": "thread_name", "ph": "M", "ts": 0.0,
                         "pid": pid, "tid": tid, "args": {"name": name}})

        by_track: dict[tuple[int, int], list[Span]] = {}
        for s in self.spans:
            by_track.setdefault((s.pid, s.tid), []).append(s)

        events = []
        for (pid, tid), spans in sorted(by_track.items()):
            # outermost-first at equal begin ts, then a stack sweep:
            # children close before (or exactly when) their parent does,
            # so B/E pairs nest by construction even under fake clocks
            # that stamp many spans at the same instant.
            spans.sort(key=lambda s: (s.ts, -s.dur))
            stack: list[tuple[Span, float]] = []

            def close(upto=None):
                while stack and (upto is None or upto >= stack[-1][1]):
                    top, end = stack.pop()
                    events.append({"name": top.name, "cat": top.cat,
                                   "ph": "E", "ts": end, "pid": pid,
                                   "tid": tid})

            for s in spans:
                close(upto=s.ts)
                end = s.ts + s.dur
                if stack:                       # clamp overlap to parent
                    end = min(end, stack[-1][1])
                events.append({"name": s.name, "cat": s.cat, "ph": "B",
                               "ts": s.ts, "pid": pid, "tid": tid,
                               "args": s.args})
                stack.append((s, end))
            close()

        for i in self.instants:
            events.append({"name": i.name, "cat": i.cat, "ph": "i",
                           "ts": i.ts, "pid": i.pid, "tid": i.tid,
                           "s": "t", "args": i.args})

        events.sort(key=lambda e: e["ts"])      # stable: per-track order
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path):
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


# -- validation ------------------------------------------------------

_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def validate_chrome_trace(obj) -> list[str]:
    """Validate a Chrome trace-event object; returns a list of problems
    (empty == valid).  Checks the schema surface CI gates on: required
    keys per event, numeric non-negative monotonically sorted ts, known
    phase types, and matched B/E pairs (stack discipline per
    (pid, tid) track, E never before its B)."""
    errs = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' key"]
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' must be a non-empty list"]

    last_ts = None
    stacks: dict[tuple, list] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not an object")
            continue
        missing = [k for k in _REQUIRED_KEYS if k not in e]
        if missing:
            errs.append(f"event {i}: missing keys {missing}")
            continue
        ph, ts = e["ph"], e["ts"]
        if ph not in _PH_ALLOWED:
            errs.append(f"event {i}: unknown ph {ph!r}")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph != "M":
            if last_ts is not None and ts < last_ts:
                errs.append(f"event {i}: ts {ts} < previous {last_ts} "
                            "(not monotonic)")
            last_ts = ts
        track = (e["pid"], e["tid"])
        if ph == "B":
            stacks.setdefault(track, []).append((e["name"], ts))
        elif ph == "E":
            stack = stacks.get(track) or []
            if not stack:
                errs.append(f"event {i}: E {e['name']!r} on track "
                            f"{track} with no open B")
                continue
            name, b_ts = stack.pop()
            if name != e.get("name", name):
                errs.append(f"event {i}: E {e['name']!r} closes B "
                            f"{name!r} on track {track}")
            if ts < b_ts:
                errs.append(f"event {i}: E ts {ts} precedes B ts {b_ts}")
    for track, stack in stacks.items():
        for name, _ in stack:
            errs.append(f"unclosed B {name!r} on track {track}")
    return errs


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.obs.trace <trace.json>")
        return 2
    with open(argv[0]) as f:
        obj = json.load(f)
    errs = validate_chrome_trace(obj)
    n = len(obj.get("traceEvents", []))
    if errs:
        for e in errs[:40]:
            print(f"INVALID: {e}")
        print(f"{argv[0]}: {len(errs)} problem(s) in {n} events")
        return 1
    print(f"{argv[0]}: valid Chrome trace ({n} events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Activation-sparsity profiling — post-ReLU zero fractions on real
traffic.

The paper exploits *weight* sparsity; ROADMAP's activation-sparsity item
starts with measuring how zero the *activations* actually are, per layer
and per ``coarse_in`` lane group (a kernel that skips an input column
group per tap needs the whole group zero, so the interesting number is
the all-zero-group cell fraction, not just the scalar element fraction).

The profiler is the host-side accumulator.  It never computes anything
itself — the conv lowerings emit per-layer count arrays (see
``kernels/ops.conv2d(zero_count=...)``): exact jnp counts on the jnp
path, a cheap per-strip zero-count output alongside the amax on the
Pallas path.  Counts are *observation-only*: they read the f32 Collector
output ``y`` that already exists, so logits are bit-identical with
profiling on (tested).

``add()`` stores device arrays without forcing a sync — JAX arrays are
only pulled to numpy at ``snapshot()`` time, so profiling doesn't
serialize the pipeline's async dispatch.
"""
from __future__ import annotations

import numpy as np

# aux keys every conv layer reports (all float32 arrays / scalars):
#   row_zeros     (N,)  zero elements per image row
#   group_zeros   (G,)  zero elements per coarse_in group, summed over rows
#   group_allzero (G,)  (image, pixel) cells whose whole group is zero
#   elems_per_row ()    H*W*C          (static, repeated per microbatch)
#   cells         ()    N*H*W          (per-group cell count this microbatch)
AUX_KEYS = ("row_zeros", "group_zeros", "group_allzero",
            "elems_per_row", "cells")


class SparsityProfiler:
    """Accumulates per-layer zero-count aux emitted by profiled conv
    lowerings; reduces to fractions + histograms at snapshot time."""

    def __init__(self, groups: int = 8, hist_buckets: int = 10):
        assert groups >= 1 and hist_buckets >= 1
        self.groups = groups
        self.hist_buckets = hist_buckets
        self._acc: dict[str, list[dict]] = {}
        self.microbatches_profiled = 0

    def add(self, aux: dict, count_microbatch: bool = True):
        """Record per-layer counts (``{layer: {aux_key: array}}``);
        arrays stay on device.  A pipeline delivers ONE microbatch's aux
        as several per-stage ``add`` calls across ticks — it passes
        ``count_microbatch`` only for stage 0 so
        ``microbatches_profiled`` counts microbatches, not stages."""
        if not aux:
            return
        if count_microbatch:
            self.microbatches_profiled += 1
        for layer, counts in aux.items():
            self._acc.setdefault(layer, []).append(counts)

    def reset(self):
        self._acc.clear()
        self.microbatches_profiled = 0

    @property
    def layers(self):
        return sorted(self._acc)

    def snapshot(self) -> dict:
        """Reduce everything accumulated so far (pulls to host).

        Per layer: overall post-ReLU ``zero_fraction``, a per-image
        zero-fraction histogram over ``hist_buckets`` equal-width
        buckets on [0, 1], and per-``coarse_in``-group element /
        all-zero-cell fractions.  Plus an ``overall`` element-weighted
        aggregate across layers.
        """
        layers = {}
        tot_zeros = 0.0
        tot_elems = 0.0
        edges = np.linspace(0.0, 1.0, self.hist_buckets + 1)
        for name in self.layers:
            chunks = self._acc[name]
            row_zeros = np.concatenate(
                [np.asarray(c["row_zeros"], dtype=np.float64)
                 for c in chunks])
            elems_per_row = float(np.asarray(chunks[0]["elems_per_row"]))
            group_zeros = np.sum(
                [np.asarray(c["group_zeros"], dtype=np.float64)
                 for c in chunks], axis=0)
            group_allzero = np.sum(
                [np.asarray(c["group_allzero"], dtype=np.float64)
                 for c in chunks], axis=0)
            cells = float(sum(float(np.asarray(c["cells"]))
                              for c in chunks))
            n_rows = int(row_zeros.shape[0])
            elems = n_rows * elems_per_row
            zeros = float(row_zeros.sum())
            frac_rows = row_zeros / max(elems_per_row, 1.0)
            hist, _ = np.histogram(frac_rows, bins=edges)
            n_groups = int(group_zeros.shape[0])
            group_elems = elems / max(n_groups, 1)
            layers[name] = {
                "n_rows": n_rows,
                "elems_per_row": elems_per_row,
                "zeros": zeros,
                "zero_fraction": zeros / max(elems, 1.0),
                "row_fraction_hist": {
                    "bucket_edges": [float(e) for e in edges],
                    "counts": [int(c) for c in hist],
                },
                "group_size": self.groups,
                "group_zero_fraction": [
                    float(z / max(group_elems, 1.0)) for z in group_zeros],
                "group_allzero_cell_fraction": [
                    float(a / max(cells, 1.0)) for a in group_allzero],
            }
            tot_zeros += zeros
            tot_elems += elems
        return {
            "groups": self.groups,
            "microbatches_profiled": self.microbatches_profiled,
            "overall_zero_fraction": tot_zeros / max(tot_elems, 1.0),
            "layers": layers,
        }

"""Shared model layers: norms, positional encodings, FFN/SwiGLU, embeddings.

All matmul weights are nn.linear_param so the paper's constant-parameter
compilation (core.compiled_linear) applies uniformly across architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.compiled_linear import apply_linear


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(key, d):
    return {"scale": nn.param(key, (d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layernorm_init(key, d):
    return {"scale": nn.param(key, (d,), ("embed",), init="ones"),
            "bias": nn.param(key, (d,), ("embed",), init="zeros")}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, head_dim, 2) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4,
               mrope_sections: tuple | None = None) -> jax.Array:
    """x: (B, T, H, D); positions: (B, T) or (3, B, T) for M-RoPE.

    M-RoPE (Qwen2-VL): the frequency axis is split into sections, each
    rotated by its own position stream (temporal / height / width).
    """
    D = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(D, theta), jnp.float32)      # (D/2,)
    if positions.ndim == 3:                                      # M-RoPE
        assert mrope_sections is not None
        sec = np.cumsum((0,) + tuple(mrope_sections))
        assert sec[-1] == D // 2, (mrope_sections, D)
        parts = []
        for i in range(len(mrope_sections)):
            ang = (positions[i].astype(jnp.float32)[..., None]
                   * freqs[sec[i]:sec[i + 1]])                   # (B,T,di)
            parts.append(ang)
        angles = jnp.concatenate(parts, axis=-1)                 # (B,T,D/2)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def sinusoidal_positions(T: int, d: int) -> np.ndarray:
    pos = np.arange(T)[:, None]
    dim = np.arange(0, d, 2)[None, :] / d
    ang = pos / (10000.0 ** dim)
    out = np.zeros((T, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


# ---------------------------------------------------------------------------
# FFN (SwiGLU / GeGLU / plain GELU)
# ---------------------------------------------------------------------------

def ffn_init(key, d, d_ff, gated=True, suffix=("ffn_in", "ffn_out")):
    ks = jax.random.split(key, 3)
    p = {"down": nn.linear_param(ks[2], d_ff, d, (suffix[1], "embed"))}
    if gated:
        p["gate"] = nn.linear_param(ks[0], d, d_ff, ("embed", suffix[0]))
        p["up"] = nn.linear_param(ks[1], d, d_ff, ("embed", suffix[0]))
    else:
        p["up"] = nn.linear_param(ks[1], d, d_ff, ("embed", suffix[0]))
    return p


def ffn(p, x, act="silu", qat=False):
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[act]
    up = apply_linear(p["up"], x, qat)
    if "gate" in p:
        h = actf(apply_linear(p["gate"], x, qat)) * up
    else:
        h = actf(up)
    return apply_linear(p["down"], h, qat)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d):
    return {"table": nn.param(key, (vocab, d), ("vocab", "embed"),
                              scale=0.02)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def lm_head_init(key, d, vocab):
    return {"w": nn.linear_param(key, d, vocab, ("embed", "vocab"))}


def lm_head(params, x, tied_embed=None, qat=False):
    if tied_embed is not None:
        return x @ tied_embed.T.astype(x.dtype)
    return apply_linear(params["w"], x, qat)

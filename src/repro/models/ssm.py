"""State-space / linear-recurrence blocks: Mamba-1 (Jamba) and RWKV-6.

Both are implemented in chunked form: sequential lax.scan across chunks
carrying the recurrent state, associative/matrix math within a chunk —
the TPU-friendly schedule (MXU-sized intra-chunk einsums, O(1) state).
Exact sequential references live in the same module for tests.

The projections route through CompiledLinear; the recurrences themselves
are activation-state math the paper's technique does not cover
(DESIGN.md SS4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.compiled_linear import apply_linear
from repro.models.layers import rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# Mamba-1 (selective SSM), Jamba flavour: d_state=16, conv=4, expand=2
# ---------------------------------------------------------------------------

def mamba_init(key, cfg):
    s = cfg.ssm
    d, di, N, R = cfg.d_model, s.d_inner, s.d_state, s.dt_rank
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A; dt bias for softplus in [1e-3, 0.1]
    A = np.tile(np.arange(1, N + 1, dtype=np.float32), (di, 1))
    dt = np.exp(np.random.RandomState(0).uniform(
        np.log(1e-3), np.log(0.1), size=di)).astype(np.float32)
    dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
    return {
        "in_proj": nn.linear_param(ks[0], d, 2 * di, ("embed", "mamba_inner")),
        "conv_w": nn.param(ks[1], (s.d_conv, di), (None, "mamba_inner"),
                           scale=1.0 / np.sqrt(s.d_conv)),
        "conv_b": nn.param(ks[2], (di,), ("mamba_inner",), init="zeros"),
        "x_proj": nn.linear_param(ks[3], di, R + 2 * N, ("mamba_inner", None)),
        "dt_proj": nn.linear_param(ks[4], R, di, (None, "mamba_inner")),
        "dt_bias": nn.Param(jnp.asarray(dt_bias), ("mamba_inner",)),
        "A_log": nn.Param(jnp.asarray(np.log(A)), ("mamba_inner", None)),
        "D": nn.param(ks[5], (di,), ("mamba_inner",), init="ones"),
        "out_proj": nn.linear_param(ks[6], di, d, ("mamba_inner", "embed")),
    }


def _mamba_scan_chunked(a, b, h0, chunk):
    """h_t = a_t * h_{t-1} + b_t over time.  a,b: (B, T, di, N)."""
    B, T, di, N = a.shape
    nc = T // chunk

    def chunk_step(h, ab):
        ac, bc = ab                                   # (B, c, di, N)
        # fold carried state into the first step
        bc = bc.at[:, 0].add(ac[:, 0] * h)

        def comb(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(comb, (ac, bc), axis=1)
        return hs[:, -1], hs

    ac = jnp.moveaxis(a.reshape(B, nc, chunk, di, N), 1, 0)
    bc = jnp.moveaxis(b.reshape(B, nc, chunk, di, N), 1, 0)
    h_last, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, T, di, N)
    return hs, h_last


def mamba_forward(p, x, cfg, state=None, qat=False, chunk=128):
    """x: (B, T, d).  state: dict(conv (B, d_conv-1, di), ssm (B, di, N))
    for decode; None for training.  Returns (y, new_state)."""
    s = cfg.ssm
    B, T, d = x.shape
    di, N, R = s.d_inner, s.d_state, s.dt_rank
    xz = apply_linear(p["in_proj"], x, qat)
    xi, z = jnp.split(xz, 2, axis=-1)                 # (B, T, di)

    # depthwise causal conv1d (k = d_conv)
    if state is not None:
        conv_in = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
        new_conv = conv_in[:, -(s.d_conv - 1):]
    else:
        conv_in = jnp.pad(xi, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(s.d_conv - 1):]
    wins = jnp.stack([conv_in[:, i:i + T] for i in range(s.d_conv)], axis=2)
    xi = jnp.einsum("btkd,kd->btd", wins, p["conv_w"].astype(xi.dtype))
    xi = jax.nn.silu(xi + p["conv_b"].astype(xi.dtype))

    proj = apply_linear(p["x_proj"], xi, qat)
    dt_r, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(apply_linear(p["dt_proj"], dt_r, qat)
                         + p["dt_bias"].astype(xi.dtype))      # (B,T,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di,N)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A)                            # (B,T,di,N)
    b = (dtf * xi.astype(jnp.float32))[..., None] * \
        Bc.astype(jnp.float32)[:, :, None, :]                  # (B,T,di,N)

    h0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, di, N), jnp.float32))
    if T == 1:
        h = a[:, 0] * h0 + b[:, 0]
        hs, h_last = h[:, None], h
    else:
        pad = (-T) % chunk
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        hs, h_last = _mamba_scan_chunked(a, b, h0, min(chunk, T + pad))
        hs = hs[:, :T]
        if pad:  # true last state is at original T
            h_last = hs[:, -1]
    y = jnp.einsum("btdn,btn->btd", hs, Cc.astype(jnp.float32))
    y = y + xi.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = apply_linear(p["out_proj"], y, qat)
    new_state = {"conv": new_conv.astype(jnp.bfloat16),
                 "ssm": h_last.astype(jnp.float32)}
    return out, new_state


def mamba_ref(p, x, cfg):
    """Exact sequential reference (tests)."""
    s = cfg.ssm
    B, T, d = x.shape

    def step(state, xt):
        y, new_state = mamba_forward(p, xt[:, None], cfg, state=state)
        return new_state, y[:, 0]

    state = mamba_state_spec(cfg, B)
    state = jax.tree.map(lambda p_: jnp.zeros(p_.value.shape, p_.value.dtype),
                         state, is_leaf=lambda q: isinstance(q, nn.Param))
    _, ys = jax.lax.scan(step, state, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(ys, 0, 1)


def mamba_state_spec(cfg, B):
    s = cfg.ssm
    return {
        "conv": nn.Param(jnp.zeros((B, s.d_conv - 1, s.d_inner), jnp.bfloat16),
                         ("batch", None, "mamba_inner_s")),
        "ssm": nn.Param(jnp.zeros((B, s.d_inner, s.d_state), jnp.float32),
                        ("batch", "mamba_inner_s", None)),
    }


# ---------------------------------------------------------------------------
# RWKV-6 ("Finch"): data-dependent decay, per-head 64x64 state
# ---------------------------------------------------------------------------

def rwkv6_init(key, cfg):
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    ks = jax.random.split(key, 12)
    lora = cfg.ssm.decay_lora
    return {
        # token-shift mix coefficients (static part; data-dependent lora)
        "mu": nn.param(ks[0], (5, d), (None, "embed"), scale=0.5),
        "mix_lora_a": nn.linear_param(ks[1], d, 5 * 32, ("embed", None)),
        "mix_lora_b": nn.param(ks[2], (5, 32, d), (None, None, "embed"),
                               scale=0.05),
        "r": nn.linear_param(ks[3], d, d, ("embed", "heads_q")),
        "k": nn.linear_param(ks[4], d, d, ("embed", "heads_q")),
        "v": nn.linear_param(ks[5], d, d, ("embed", "heads_q")),
        "g": nn.linear_param(ks[6], d, d, ("embed", "heads_q")),
        "w_lora_a": nn.linear_param(ks[7], d, lora, ("embed", None)),
        "w_lora_b": nn.linear_param(ks[8], lora, d, (None, "heads_q")),
        "w_bias": nn.param(ks[9], (d,), ("embed",), init="zeros"),
        "u": nn.param(ks[10], (H, hd), ("heads_s", None), scale=0.5),
        "ln_x": rmsnorm_init(ks[11], d),
        "o": nn.linear_param(ks[11], d, d, ("heads_q", "embed")),
    }


def _rwkv_chunk(r, k, v, w, u, S0, chunk):
    """Chunked WKV.  r,k,v: (B, H, T, D); w: (B, H, T, D) decay in (0,1);
    u: (H, D) bonus.  Returns y (B,H,T,D), S_last (B,H,D,D)."""
    B, H, T, D = r.shape
    nc = T // chunk

    def step(S, inp):
        rc, kc, vc, wc = inp                          # (B,H,c,D)
        logw = jnp.log(jnp.maximum(wc, 1e-38))
        cw = jnp.cumsum(logw, axis=2)                 # inclusive
        # inter-chunk: state contribution (decay up to t-1 -> exclusive)
        dec_q = jnp.exp(cw - logw)                    # prod_{r<t} w_r
        y_inter = jnp.einsum("bhtd,bhde->bhte", rc * dec_q, S)
        # intra-chunk pairs s < t: a[t,s] = sum_d r_t k_s exp(cw_{t-1}-cw_s),
        # factored through a mid-chunk reference for f32 stability (GLA
        # secondary normalization; clip only guards vanishing tails).
        m_ref = cw[:, :, chunk // 2][:, :, None, :]   # (B,H,1,D)
        r_t = rc * jnp.exp(jnp.clip(cw - logw - m_ref, -60.0, 60.0))
        k_s = kc * jnp.exp(jnp.clip(m_ref - cw, -60.0, 60.0))
        mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
        a = jnp.einsum("bhtd,bhsd->bhts", r_t, k_s) * mask[None, None]
        a_diag = jnp.einsum("bhtd,bhtd,hd->bht", rc, kc,
                            u)                        # bonus at s == t
        y_intra = (jnp.einsum("bhts,bhsd->bhtd", a, vc)
                   + a_diag[..., None] * vc)
        # state update: S' = diag(prod w) S + sum_s (prod_{r>s} w ∘ k_s) v_s
        dec_tail = jnp.exp(cw[:, :, -1:, :] - cw)     # prod_{r>s} w_r
        S_new = (S * jnp.exp(cw[:, :, -1])[..., None]
                 + jnp.einsum("bhsd,bhse->bhde", kc * dec_tail, vc))
        return S_new, y_inter + y_intra

    rs = jnp.moveaxis(r.reshape(B, H, nc, chunk, D), 2, 0)
    ks_ = jnp.moveaxis(k.reshape(B, H, nc, chunk, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, H, nc, chunk, D), 2, 0)
    ws = jnp.moveaxis(w.reshape(B, H, nc, chunk, D), 2, 0)
    S_last, ys = jax.lax.scan(step, S0, (rs, ks_, vs, ws))
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, T, D)
    return y, S_last


def rwkv6_forward(p, x, cfg, state=None, qat=False, chunk=64):
    """x: (B, T, d).  state: dict(shift (B,1,d), wkv (B,H,D,D))."""
    hd = cfg.ssm.head_dim
    B, T, d = x.shape
    H = d // hd
    xf = x.astype(jnp.float32)
    if state is not None:
        prev = jnp.concatenate([state["shift"].astype(xf.dtype),
                                xf[:, :-1]], axis=1)
    else:
        prev = jnp.pad(xf, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    new_shift = xf[:, -1:]
    # data-dependent token-shift mix (ddlerp)
    mu = p["mu"].astype(jnp.float32)
    base = xf + (prev - xf) * 0.5
    lora = jnp.tanh(apply_linear(p["mix_lora_a"], base.astype(x.dtype), qat))
    lora = lora.reshape(B, T, 5, 32).astype(jnp.float32)
    dyn = jnp.einsum("btfk,fkd->btfd", lora, p["mix_lora_b"].astype(jnp.float32))
    mixed = xf[:, :, None] + (prev - xf)[:, :, None] * \
        (mu[None, None] + dyn)                        # (B,T,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i].astype(x.dtype) for i in range(5)]

    r = apply_linear(p["r"], xr, qat).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    k = apply_linear(p["k"], xk, qat).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    v = apply_linear(p["v"], xv, qat).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(apply_linear(p["g"], xg, qat))
    w_raw = (apply_linear(p["w_lora_b"],
                          jnp.tanh(apply_linear(p["w_lora_a"], xw, qat)), qat)
             + p["w_bias"].astype(x.dtype))
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32)))  # decay in (0,1)
    w = w.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))

    S0 = (state["wkv"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))
    if T == 1:
        rt, kt, vt, wt = rf[:, :, 0], kf[:, :, 0], vf[:, :, 0], w[:, :, 0]
        u = p["u"].astype(jnp.float32)
        y = jnp.einsum("bhd,bhde->bhe", rt, S0) + \
            jnp.einsum("bhd,bhd,hd,bhe->bhe", rt, kt, u, vt)
        S_last = S0 * wt[..., None] + kt[..., None] * vt[:, :, None]
        y = y[:, :, None]
    else:
        pad = (-T) % chunk
        if pad:
            rf = jnp.pad(rf, ((0, 0), (0, 0), (0, pad), (0, 0)))
            kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
            w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)),
                        constant_values=1.0)
        y, S_last = _rwkv_chunk(rf, kf, vf, w, p["u"].astype(jnp.float32),
                                S0, min(chunk, rf.shape[2]))
        y = y[:, :, :T]
        if pad:  # state advanced through padded (decay-1, k=0) steps: exact
            pass
    y = y.transpose(0, 2, 1, 3).reshape(B, T, d).astype(x.dtype)
    y = rmsnorm(p["ln_x"], y) * g
    out = apply_linear(p["o"], y, qat)
    new_state = {"shift": new_shift.astype(jnp.bfloat16),
                 "wkv": S_last.astype(jnp.float32)}
    return out, new_state


def rwkv6_state_spec(cfg, B):
    hd = cfg.ssm.head_dim
    H = cfg.d_model // hd
    return {
        "shift": nn.Param(jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16),
                          ("batch", None, "embed_s")),
        "wkv": nn.Param(jnp.zeros((B, H, hd, hd), jnp.float32),
                        ("batch", "heads_s", None, None)),
    }

"""MobileNetV2 — inverted residuals + depthwise convs as a Compiled NN.

The second model-zoo member (DESIGN.md §12).  Two structural features
exercise paths ResNet50 never touches:

* **depthwise 3x3 convs** (groups == channels) compile to the tap-MAC
  kernel (kernels/conv_depthwise.py) via the ``dwconv`` Param kind —
  implicit-GEMM would waste c_in x multiplies on a diagonal matmul;
* **linear bottlenecks**: the projection conv has NO ReLU but still emits
  a quantized edge.  The Collector epilogue's amax is max|y| (signed
  symmetric int8), so ``relu=False, quant_out=True`` needs no new kernel
  code — the existing epilogue covers it.

Block structure (t = expansion, per Table 2 of the MobileNetV2 paper):
expand 1x1 (skipped when t == 1) → depthwise 3x3 (stride) → project 1x1
(linear), with the identity shortcut riding the project conv's Collector
whenever stride == 1 and c_in == c_out.  Deviation from the paper's
training recipe: plain ReLU instead of ReLU6 — the clamp exists to aid
low-precision TRAINING, while this repo compiles post-training params
and the activation quantizer already bounds the range (DESIGN.md §12).

Graph cuts: residual blocks are one pipeline unit (the block input stays
live for the shortcut, so no interior edge is an articulation cut);
non-residual blocks split at their expand/dw/project edges into finer
units — legal cuts, finer stage-planning granularity for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.compiled_linear import apply_linear
from repro.models.graph import Graph, Node, apply_graph
from repro.models.resnet import _conv_apply, _conv_init

__all__ = ["MOBILENET_V2_BLOCKS", "MobileNetV2Config", "block_specs",
           "init", "apply", "mobilenet_v2_graph"]

# (expansion t, out channels c, repeats n, first stride s) — Table 2.
MOBILENET_V2_BLOCKS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def _ch(c: int, w: float) -> int:
    """Width-scaled channel count, floored to the int8 tile-friendly
    multiple of 8 the kernels want."""
    return max(8, (int(c * w) // 8) * 8)


@dataclasses.dataclass(frozen=True)
class MobileNetV2Config:
    width_mult: float = 1.0
    num_classes: int = 1000
    in_hw: int = 224

    def graph(self) -> Graph:
        return mobilenet_v2_graph(self)

    def init(self, key):
        return init(key, self)

    def apply(self, params, x):
        return apply(params, x, self)


def block_specs(cfg: MobileNetV2Config) -> list:
    """Flattened per-block (t, c_in, c_mid, c_out, stride) chain."""
    out = []
    in_ch = _ch(32, cfg.width_mult)
    for t, c, n, s in MOBILENET_V2_BLOCKS:
        for i in range(n):
            c_out = _ch(c, cfg.width_mult)
            out.append((t, in_ch, t * in_ch, c_out, s if i == 0 else 1))
            in_ch = c_out
    return out


# ---------------------------------------------------------------------------
# Functional model
# ---------------------------------------------------------------------------

def _dw_init(key, c, k, stride):
    return {
        "w": nn.dwconv_param(key, c, k, stride, ("conv_in", "conv_out")),
        "scale": nn.param(key, (c,), ("conv_out",), init="ones"),
        "bias": nn.param(key, (c,), ("conv_out",), init="zeros"),
    }


def _dw_apply(p, x, k, stride, relu=True):
    """Dense-path depthwise conv: grouped XLA conv over the tap-major
    (k*k, C) weight + separate NK collector ops — the float reference the
    compiled tap-MAC kernel path is validated against."""
    w = p["w"].value if isinstance(p["w"], nn.Param) else p["w"]
    c = x.shape[-1]
    y = jax.lax.conv_general_dilated(
        x, w.reshape(k, k, 1, c), (stride, stride), "SAME",
        feature_group_count=c,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y * p["scale"] + p["bias"]
    return jax.nn.relu(y) if relu else y


def init(key, cfg: MobileNetV2Config):
    keys = iter(jax.random.split(key, 4 + 3 * len(block_specs(cfg))))
    params = {"stem": _conv_init(next(keys), 3, _ch(32, cfg.width_mult), 3,
                                 stride=2)}
    blocks = []
    for t, c_in, c_mid, c_out, stride in block_specs(cfg):
        blk = {}
        if t != 1:
            blk["ex"] = _conv_init(next(keys), c_in, c_mid, 1)
        blk["dw"] = _dw_init(next(keys), c_mid, 3, stride)
        blk["pj"] = _conv_init(next(keys), c_mid, c_out, 1)
        blocks.append(blk)
    params["blocks"] = blocks
    tail_ch = _ch(1280, cfg.width_mult)
    params["tail"] = _conv_init(next(keys), block_specs(cfg)[-1][3],
                                tail_ch, 1)
    params["head"] = {"w": nn.linear_param(next(keys), tail_ch,
                                           cfg.num_classes,
                                           ("embed", "classes"))}
    return params


def mobilenet_v2_graph(cfg: MobileNetV2Config) -> Graph:
    """MobileNetV2 as a conv-DAG: stem 3x3/s2, inverted-residual blocks
    (expand → depthwise → linear project, the identity shortcut riding the
    project conv's epilogue), the 1x1 tail conv, and the pooled head.
    Every conv emits a quantized edge (``quant_out``), including the
    no-ReLU projections — symmetric int8 needs only max|y|."""
    nodes = [
        Node("image", "input"),
        Node("stem_in", "quant", ("image",), unit="stem"),
        Node("stem", "conv", ("stem_in",), path=("stem",), k=3, stride=2,
             c_in=3, c_out=_ch(32, cfg.width_mult), quant_out=True),
    ]
    prev = "stem"
    for j, (t, c_in, c_mid, c_out, stride) in enumerate(block_specs(cfg)):
        u = f"block{j+1}"
        residual = stride == 1 and c_in == c_out
        src = prev
        if t != 1:
            nodes.append(Node(f"{u}/ex", "conv", (prev,),
                              path=("blocks", j, "ex"), k=1, c_in=c_in,
                              c_out=c_mid, quant_out=True, unit=u))
            src = f"{u}/ex"
        nodes.append(Node(f"{u}/dw", "dwconv", (src,),
                          path=("blocks", j, "dw"), k=3, stride=stride,
                          c_in=c_mid, c_out=c_mid, quant_out=True, unit=u))
        sc = None
        if residual:
            sc = f"{u}/id"
            nodes.append(Node(sc, "dequant", (prev,), unit=u))
        nodes.append(Node(f"{u}/pj", "conv", (f"{u}/dw",),
                          path=("blocks", j, "pj"), k=1, c_in=c_mid,
                          c_out=c_out, relu=False, quant_out=True,
                          shortcut=sc, unit=u))
        prev = f"{u}/pj"
    nodes.append(Node("tail", "conv", (prev,), path=("tail",), k=1,
                      c_in=block_specs(cfg)[-1][3],
                      c_out=_ch(1280, cfg.width_mult), quant_out=True,
                      unit="tail"))
    nodes.append(Node("head", "head", ("tail",), path=("head",)))
    return Graph("mobilenet_v2", tuple(nodes), cfg.in_hw, 3,
                 cfg.num_classes)


def apply(params, x, cfg: MobileNetV2Config):
    """x: (B, H, W, 3) -> logits.  Compiled constant params run the graph
    path; dense (unboxed float) params run the XLA reference."""
    if isinstance(params["stem"]["w"], dict):      # compiled constant params
        return apply_graph(mobilenet_v2_graph(cfg), params, x)
    h = _conv_apply(params["stem"], x, 3, stride=2)
    for p, (t, c_in, c_mid, c_out, stride) in zip(params["blocks"],
                                                  block_specs(cfg)):
        h0 = h
        y = _conv_apply(p["ex"], h, 1) if "ex" in p else h
        y = _dw_apply(p["dw"], y, 3, stride)
        y = _conv_apply(p["pj"], y, 1, relu=False)
        h = y + h0 if (stride == 1 and c_in == c_out) else y
    h = _conv_apply(params["tail"], h, 1)
    pooled = jnp.mean(h, axis=(1, 2))
    return apply_linear(params["head"]["w"], pooled)

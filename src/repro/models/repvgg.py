"""RepVGG — structural re-parameterization as compile-time branch fusion.

The third model-zoo member (DESIGN.md §12).  Training-time RepVGG blocks
have three parallel branches — a 3x3 conv, a 1x1 conv, and (when
stride == 1 and c_in == c_out) an identity — each with its own folded-BN
per-channel scale/bias.  Because convolution is linear, the three fold
into ONE 3x3 conv ahead of time:

    Wf = W3·g3 + embed(W1·g1) + embed(I·gid),   bf = b3 + b1 + bid

where ``embed`` places a 1x1 weight on the 3x3 kernel's center tap.  In
the channel-major flat layout (c_in*k*k, c_out) the center-tap rows are
exactly ``4::9`` (tap dy*3+dx = 4 within each input channel's 9 rows), so
the fold is two ``at[4::9].add`` updates — no layout shuffles.

``fuse_params`` is the natural extension of this repo's thesis: the
paper freezes parameters into the bitstream at compile time, so ANY
parameter-only algebra is free at serve time.  The fused network is a
plain sequential chain of 3x3 convs — every edge is an articulation cut,
giving the pipeline planner maximum granularity — and it is validated
against the unfused three-branch reference (tests/test_graph.py).

Stride-2 subtlety: with SAME padding (pad_lo = total//2 = 0 for k=1) a
TRUE strided 1x1 conv samples even pixels while the 3x3 center tap sits
at odd offsets, so "1x1 branch == center-embedded 3x3" holds exactly only
at stride 1.  RepVGG's published fusion (and ours) therefore DEFINES the
1x1 branch as the center-embedded 3x3 conv; the unfused reference applies
it the same way, and a stride-1 test pins embed == true 1x1 where the
identity does hold.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.compiled_linear import apply_linear
from repro.models.graph import Graph, Node, apply_graph
from repro.models.resnet import _conv_apply, _conv_init

__all__ = ["REPVGG_A0_STAGES", "RepVGGConfig", "block_specs", "init",
           "fuse_params", "apply", "repvgg_graph", "embed_1x1"]

# (out channels, blocks) per stage — RepVGG-A0; the first block of every
# stage has stride 2 (input stage included: 224 -> 112 at the stem block).
REPVGG_A0_STAGES = [(48, 1), (48, 2), (96, 4), (192, 14), (1280, 1)]


def _ch(c: int, w: float) -> int:
    return max(8, int(c * w))


@dataclasses.dataclass(frozen=True)
class RepVGGConfig:
    width_mult: float = 1.0
    num_classes: int = 1000
    in_hw: int = 224

    def graph(self) -> Graph:
        return repvgg_graph(self)

    def init(self, key):
        return init(key, self)

    def fuse(self, params):
        return fuse_params(params, self)

    def apply(self, params, x):
        return apply(params, x, self)


def block_specs(cfg: RepVGGConfig) -> list:
    """Flattened per-block (name, c_in, c_out, stride, identity) chain."""
    out, in_ch = [], 3
    for i, (c, n) in enumerate(REPVGG_A0_STAGES):
        c_out = _ch(c, cfg.width_mult)
        for b in range(n):
            stride = 2 if b == 0 else 1
            ident = stride == 1 and in_ch == c_out
            out.append((f"stage{i+1}_{b+1}", in_ch, c_out, stride, ident))
            in_ch = c_out
    return out


# ---------------------------------------------------------------------------
# Functional model (unfused training-time form)
# ---------------------------------------------------------------------------

def init(key, cfg: RepVGGConfig):
    """Unfused three-branch params: blocks[j] = {conv3, conv1[, id]}."""
    specs = block_specs(cfg)
    keys = iter(jax.random.split(key, 2 + 4 * len(specs)))
    blocks = []
    for name, c_in, c_out, stride, ident in specs:
        blk = {"conv3": _conv_init(next(keys), c_in, c_out, 3, stride=stride),
               "conv1": _conv_init(next(keys), c_in, c_out, 1, stride=stride)}
        if ident:
            blk["id"] = {
                "scale": nn.param(next(keys), (c_out,), ("conv_out",),
                                  init="ones"),
                "bias": nn.param(next(keys), (c_out,), ("conv_out",),
                                 init="zeros"),
            }
        blocks.append(blk)
    return {"blocks": blocks,
            "head": {"w": nn.linear_param(next(keys), specs[-1][2],
                                          cfg.num_classes,
                                          ("embed", "classes"))}}


def embed_1x1(w1, c_in, k=3):
    """Embed a 1x1 conv weight (c_in, c_out) on the center tap of a kxk
    conv in the channel-major flat layout: rows c*k*k + center."""
    kk, center = k * k, (k * k) // 2
    wf = jnp.zeros((c_in * kk, w1.shape[1]), w1.dtype)
    return wf.at[center::kk].add(w1)


def _val(p):
    return p.value if isinstance(p, nn.Param) else p


def fuse_params(params, cfg: RepVGGConfig):
    """Compile-time branch fusion: fold the 3x3/1x1/identity branches and
    their per-channel scales into ONE 3x3 conv per block (scale = 1,
    bias = sum of branch biases).  Returns a boxed Param tree ready for
    ``compile_params`` — parameter-only algebra, free under the paper's
    constant-parameter regime."""
    fused = []
    for blk, (name, c_in, c_out, stride, ident) in zip(params["blocks"],
                                                       block_specs(cfg)):
        w3, g3 = _val(blk["conv3"]["w"]), _val(blk["conv3"]["scale"])
        w1, g1 = _val(blk["conv1"]["w"]), _val(blk["conv1"]["scale"])
        wf = w3 * g3 + embed_1x1(w1 * g1, c_in)
        bf = _val(blk["conv3"]["bias"]) + _val(blk["conv1"]["bias"])
        if ident:
            gid = _val(blk["id"]["scale"])
            wf = wf.at[4::9].add(jnp.diag(gid.astype(wf.dtype)))
            bf = bf + _val(blk["id"]["bias"])
        fused.append({
            "w": nn.Param(wf, ("conv_in", "conv_out"),
                          kind=nn.conv_kind(3, stride)),
            "scale": nn.Param(jnp.ones((c_out,), wf.dtype), ("conv_out",)),
            "bias": nn.Param(bf, ("conv_out",)),
        })
    return {"blocks": fused, "head": params["head"]}


def repvgg_graph(cfg: RepVGGConfig) -> Graph:
    """The FUSED network as a conv-DAG: a pure sequential chain of 3x3
    quant-out convs — every block edge is an articulation cut, so the
    pipeline planner gets per-block granularity."""
    specs = block_specs(cfg)
    nodes = [Node("image", "input"),
             Node("in_q", "quant", ("image",), unit=specs[0][0])]
    prev = "in_q"
    for j, (name, c_in, c_out, stride, _) in enumerate(specs):
        nodes.append(Node(name, "conv", (prev,), path=("blocks", j), k=3,
                          stride=stride, c_in=c_in, c_out=c_out,
                          quant_out=True, unit=name))
        prev = name
    nodes.append(Node("head", "head", (prev,), path=("head",)))
    return Graph("repvgg_a0", tuple(nodes), cfg.in_hw, 3, cfg.num_classes)


def apply(params, x, cfg: RepVGGConfig):
    """x: (B, H, W, 3) -> logits.

    Dispatch: compiled fused params run the graph path; dense fused params
    run a plain 3x3 chain; dense UNFUSED params run the three-branch
    reference (the pre-fusion baseline ``fuse_params`` is tested against).
    """
    blk0 = params["blocks"][0]
    if "conv3" not in blk0 and isinstance(blk0["w"], dict):
        return apply_graph(repvgg_graph(cfg), params, x)     # compiled fused
    h = x
    for p, (name, c_in, c_out, stride, ident) in zip(params["blocks"],
                                                     block_specs(cfg)):
        if "conv3" in p:                                     # unfused
            y = _conv_apply(p["conv3"], h, 3, stride, relu=False)
            # the 1x1 branch is DEFINED as its center-tap 3x3 embedding
            # (see module docstring: strided SAME sampling differs)
            w1 = {"w": embed_1x1(_val(p["conv1"]["w"]), c_in),
                  "scale": p["conv1"]["scale"], "bias": p["conv1"]["bias"]}
            y = y + _conv_apply(w1, h, 3, stride, relu=False)
            if ident:
                y = y + (h * p["id"]["scale"] + p["id"]["bias"])
            h = jax.nn.relu(y)
        else:                                                # fused dense
            h = _conv_apply(p, h, 3, stride)
    pooled = jnp.mean(h, axis=(1, 2))
    return apply_linear(params["head"]["w"], pooled)

"""Mixture-of-Experts FFN: top-k routing with capacity, scatter dispatch,
optional shared experts (DeepSeek-V2) — expert dim shards over 'model'
(expert parallelism); dispatch/combine lower to all-to-all under pjit.

Router stays fp32 and is excluded from constant-parameter compilation
(routing stability); expert weights are stacked (E, d, d_ff) linear Params
so compile_params packs them per expert.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.compiled_linear import apply_linear
from repro.models.layers import ffn, ffn_init


def moe_init(key, cfg):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    p = {"router": nn.param(ks[0], (cfg.d_model, m.n_experts),
                            ("embed", "experts"), scale=0.02)}
    p["experts"] = nn.vmap_init(
        lambda k: ffn_init(k, cfg.d_model, m.d_ff_expert, gated=m.gated,
                           suffix=("ffn_in", "ffn_out")),
        ks[1], m.n_experts)
    # stacked leading axis is the expert dim, not 'layers'
    p["experts"] = jax.tree.map(
        lambda q: nn.Param(q.value, ("experts_stack",) + q.axes[1:], q.kind),
        p["experts"], is_leaf=lambda x: isinstance(x, nn.Param))
    if m.n_shared > 0:
        p["shared"] = ffn_init(ks[2], cfg.d_model,
                               m.d_ff_expert * m.n_shared, gated=m.gated)
    return p


def moe_forward(p, x, cfg, qat=False, capacity_factor=1.25):
    """x: (B, T, d) -> (B, T, d); also returns aux losses dict."""
    m = cfg.moe
    B, T, d = x.shape
    E, K = m.n_experts, m.top_k
    xt = x.reshape(B * T, d)
    n_tok = B * T

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (N, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    cap = int(max(8, -(-n_tok * K // E) * capacity_factor))
    cap = min(cap, n_tok)
    cap = ((cap + 7) // 8) * 8

    # position of each (token, choice) within its expert queue
    flat_e = expert_idx.reshape(-1)                            # (N*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < cap
    tok_id = jnp.repeat(jnp.arange(n_tok), K)

    # dispatch: scatter tokens into (E, cap, d); dropped slots fall off the
    # end (mode='drop'), implementing the capacity overflow drop.  The
    # sharding constraint forces the (tokens over data) -> (experts over
    # model) boundary to lower as an all-to-all instead of a replicate.
    from repro.distributed.sharding import shard
    x_e = jnp.zeros((E, cap, d), x.dtype)
    x_e = x_e.at[flat_e, jnp.where(keep, slot, cap)].set(
        xt[tok_id], mode="drop")
    x_e = shard(x_e, "experts_stack", None, None)
    y_e = jax.vmap(lambda w, xe: ffn(w, xe, act=m.act, qat=qat))(
        p["experts"], x_e)                                     # (E, cap, d)
    y_e = shard(y_e, "experts_stack", None, None)

    # combine: gather each kept (token, choice) result, weight, accumulate
    gathered = y_e[flat_e, jnp.where(keep, slot, 0)]           # (N*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_vals.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.zeros_like(xt).at[tok_id].add(gathered * w)

    if "shared" in p:
        out = out + ffn(p["shared"], xt, act=m.act, qat=qat)

    # aux: load-balance loss (Switch) + router z-loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32),
                  axis=0)
    aux = {
        "lb_loss": E * jnp.sum(me * ce),
        "z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(B, T, d), aux

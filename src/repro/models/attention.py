"""Attention: GQA full/sliding-window with chunked-flash compute, decode
with preallocated KV caches, and MLA (DeepSeek-V2 latent attention) with
the absorbed decode path.

The chunked streaming-softmax implementation is the pure-JAX lowering used
everywhere (CPU dry-run included); on TPU the same schedule is the natural
Pallas flash kernel.  QK^T/AV are activation x activation products — the
paper's constant-parameter technique does not apply to them (DESIGN.md
SS4); all projections do route through CompiledLinear.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.compiled_linear import apply_linear
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Chunked flash attention (training / prefill)
# ---------------------------------------------------------------------------

def _chunk_attn(q, k, v, qpos0, kpos0, causal, window):
    """One (q-chunk, kv-chunk) tile -> (scores-applied values, m, l).

    q: (B, KVH, G, Tq, D) — G query groups share one KV head (GQA without
    materializing repeated K/V: a G-fold KV-traffic saving, SSPerf);
    k: (B, KVH, Tk, D); v: (B, KVH, Tk, Dv).
    """
    Tq, Tk = q.shape[-2], k.shape[-2]
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k) / \
        jnp.sqrt(q.shape[-1]).astype(q.dtype)
    s = s.astype(jnp.float32)
    qpos = qpos0 + jnp.arange(Tq)[:, None]
    kpos = kpos0 + jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (b,h,g,q)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return o.astype(jnp.float32), m, l


def flash_attention(q, k, v, causal=True, window=None,
                    q_chunk=1024, kv_chunk=1024):
    """Streaming-softmax attention, GQA-native.

    q: (B, H, T, D) or (B, KVH, G, T, D); k: (B, KVH, Tk, D);
    v: (B, KVH, Tk, Dv).  K/V are never expanded across query groups —
    each KV chunk is read once and serves all G groups."""
    squeeze_g = q.ndim == 4
    if squeeze_g:
        q = q[:, :, None]
    B, KVH, G, Tq, D = q.shape
    Dv = v.shape[-1]  # may differ from D (MLA)
    Tk = k.shape[2]
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    nq = -(-Tq // q_chunk)
    nk = -(-Tk // kv_chunk)
    pad_q = nq * q_chunk - Tq
    pad_k = nk * kv_chunk - Tk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    offset = Tk - Tq  # queries sit at the end of the kv sequence

    kc = k.reshape(B, KVH, nk, kv_chunk, D)
    vc = v.reshape(B, KVH, nk, kv_chunk, Dv)

    def q_block(qi, q_i):
        qpos0 = qi * q_chunk + offset

        def kv_step(carry, inputs):
            o, m, l = carry
            ki, k_i, v_i = inputs
            kpos0 = ki * kv_chunk
            o_new, m_new, l_new = _chunk_attn(q_i, k_i, v_i, qpos0, kpos0,
                                              causal, window)
            m_tot = jnp.maximum(m, m_new)
            a_old = jnp.exp(m - m_tot)
            a_new = jnp.exp(m_new - m_tot)
            o = o * a_old[..., None] + o_new * a_new[..., None]
            l = l * a_old + l_new * a_new
            return (o, m_tot, l), None

        o0 = jnp.zeros((B, KVH, G, q_chunk, Dv), jnp.float32)
        m0 = jnp.full((B, KVH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        ks = jnp.arange(nk)
        (o, m, l), _ = jax.lax.scan(
            kv_step, (o0, m0, l0),
            (ks, jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0)))
        return o / jnp.maximum(l[..., None], 1e-30)

    qs = q.reshape(B, KVH, G, nq, q_chunk, D)
    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), jnp.moveaxis(qs, 3, 0)))
    out = jnp.moveaxis(out, 0, 3).reshape(B, KVH, G, nq * q_chunk, Dv)
    out = out[:, :, :, :Tq].astype(v.dtype)
    return out[:, :, 0] if squeeze_g else out


def gqa_attention(q, k, v, causal=True, window=None, **kw):
    """q: (B, Tq, H, D); k: (B, Tk, KVH, D); v: (B, Tk, KVH, Dv)."""
    B, Tq, H, D = q.shape
    KVH = k.shape[2]
    Dv = v.shape[-1]
    G = H // KVH
    qg = q.reshape(B, Tq, KVH, G, D).transpose(0, 2, 3, 1, 4)  # B,KVH,G,T,D
    o = flash_attention(qg, k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal, window, **kw)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, Dv)


# ---------------------------------------------------------------------------
# GQA block (init / forward / decode)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg):
    ks = jax.random.split(key, 4)
    d, H, KVH, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "q": nn.linear_param(ks[0], d, H * D, ("embed", "heads_q")),
        "k": nn.linear_param(ks[1], d, KVH * D, ("embed", "heads_kv")),
        "v": nn.linear_param(ks[2], d, KVH * D, ("embed", "heads_kv")),
        "o": nn.linear_param(ks[3], H * D, d, ("heads_q", "embed")),
    }
    if cfg.qk_norm:
        p["qn"] = rmsnorm_init(ks[0], D)
        p["kn"] = rmsnorm_init(ks[1], D)
    return p


def gqa_forward(p, x, cfg, positions, window=None, causal=True,
                cache=None, cross_kv=None, qat=False):
    """Returns (out, new_cache).  cache: dict(k,v: (B, S_max, KVH, D),
    length: int32 scalar) for decode; cross_kv: precomputed (k, v) for
    encoder-decoder cross attention (no cache update)."""
    B, T, d = x.shape
    H, KVH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = apply_linear(p["q"], x, qat).reshape(B, T, H, D)
    if cross_kv is None:
        k = apply_linear(p["k"], x, qat).reshape(B, T, KVH, D)
        v = apply_linear(p["v"], x, qat).reshape(B, T, KVH, D)
    else:
        k, v = cross_kv
    if "qn" in p:
        q = rmsnorm(p["qn"], q)
        if cross_kv is None:
            k = rmsnorm(p["kn"], k)
    if cfg.pos == "rope" or cfg.pos == "mrope":
        sections = cfg.mrope_sections if cfg.pos == "mrope" else None
        q = apply_rope(q, positions, cfg.rope_theta, sections)
        if cross_kv is None:
            k = apply_rope(k, positions, cfg.rope_theta, sections)

    new_cache = None
    if cache is not None and cross_kv is None:
        quantized = cache["k"].dtype == jnp.int8
        if quantized:  # int8 KV (per-token/head scales) — the paper's
            # "activations rounded to 8 bits" extended to the cache
            k_w, k_s = _kv_quant(k)
            v_w, v_s = _kv_quant(v)
        else:
            k_w, v_w = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        if T == 1:  # decode: append to cache
            idx = cache["length"]
            start = (0, idx, 0, 0)
            ck = jax.lax.dynamic_update_slice(cache["k"], k_w, start)
            cv = jax.lax.dynamic_update_slice(cache["v"], v_w, start)
            new_cache = {"k": ck, "v": cv, "length": idx + 1}
            scales = None
            if quantized:
                cks = jax.lax.dynamic_update_slice(cache["k_s"], k_s,
                                                   (0, idx, 0))
                cvs = jax.lax.dynamic_update_slice(cache["v_s"], v_s,
                                                   (0, idx, 0))
                new_cache.update({"k_s": cks, "v_s": cvs})
                scales = (cks, cvs)
            out = decode_attention(q, ck, cv, idx + 1, window, scales)
            return apply_linear(p["o"], out.reshape(B, 1, H * D), qat), new_cache
        else:       # prefill: write whole prompt
            start = (0, 0, 0, 0)
            ck = jax.lax.dynamic_update_slice(cache["k"], k_w, start)
            cv = jax.lax.dynamic_update_slice(cache["v"], v_w, start)
            new_cache = {"k": ck, "v": cv, "length": jnp.int32(T)}
            if quantized:
                new_cache["k_s"] = jax.lax.dynamic_update_slice(
                    cache["k_s"], k_s, (0, 0, 0))
                new_cache["v_s"] = jax.lax.dynamic_update_slice(
                    cache["v_s"], v_s, (0, 0, 0))
    o = gqa_attention(q, k, v, causal=causal, window=window)
    return apply_linear(p["o"], o.reshape(B, T, H * D), qat), new_cache


def _kv_quant(x):
    """Per-(token, head) symmetric int8: x (B, T, KVH, D) -> codes, scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0                 # (B, T, KVH)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def decode_attention(q, ck, cv, length, window=None, scales=None):
    """Single-token attention against the cache.  q: (B, 1, H, D);
    ck/cv: (B, S_max, KVH, D); scales: (k_s, v_s) (B, S_max, KVH) when the
    cache is int8-quantized."""
    B, S, KVH, D = ck.shape
    H = q.shape[2]
    G = H // KVH
    qh = q.reshape(B, KVH, G, D)
    out_dtype = q.dtype
    if scales is not None:  # int8 cache: dequant with per-token scales
        ck = ck.astype(jnp.float32) * scales[0].astype(jnp.float32)[..., None]
        cv = cv.astype(jnp.float32) * scales[1].astype(jnp.float32)[..., None]
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                   ck.astype(jnp.float32)) / jnp.sqrt(D)
    pos = jnp.arange(S)[None, None, None, :]
    valid = pos < length
    if window is not None:
        valid &= pos >= length - window
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, cv.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(out_dtype)


def gqa_cache_spec(cfg, B, S_max, dtype=jnp.bfloat16):
    KVH, D = cfg.n_kv_heads, cfg.head_dim
    spec = {
        "k": nn.Param(jnp.zeros((B, S_max, KVH, D), dtype),
                      ("batch", "kv_seq", "heads_kv_sharded", None)),
        "v": nn.Param(jnp.zeros((B, S_max, KVH, D), dtype),
                      ("batch", "kv_seq", "heads_kv_sharded", None)),
        "length": nn.Param(jnp.zeros((), jnp.int32), ()),
    }
    if dtype == jnp.int8:  # per-(token, head) dequant scales
        for s in ("k_s", "v_s"):
            spec[s] = nn.Param(jnp.zeros((B, S_max, KVH), jnp.bfloat16),
                               ("batch", "kv_seq", "heads_kv_sharded"))
    return spec


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2), absorbed decode path
# ---------------------------------------------------------------------------

def mla_init(key, cfg):
    m = cfg.mla
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.n_heads
    return {
        "q": nn.linear_param(ks[0], d, H * (m.qk_nope + m.qk_rope),
                             ("embed", "heads_q")),
        "kv_down": nn.linear_param(ks[1], d, m.kv_lora + m.qk_rope,
                                   ("embed", "kv_lora")),
        "kv_norm": rmsnorm_init(ks[2], m.kv_lora),
        "k_up": nn.linear_param(ks[3], m.kv_lora, H * m.qk_nope,
                                ("kv_lora", "heads_q")),
        "v_up": nn.linear_param(ks[4], m.kv_lora, H * m.v_dim,
                                ("kv_lora", "heads_q")),
        "o": nn.linear_param(ks[5], H * m.v_dim, d, ("heads_q", "embed")),
    }


def mla_forward(p, x, cfg, positions, cache=None, qat=False):
    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    q = apply_linear(p["q"], x, qat).reshape(B, T, H, m.qk_nope + m.qk_rope)
    q_nope, q_rope = q[..., :m.qk_nope], q[..., m.qk_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    down = apply_linear(p["kv_down"], x, qat)
    c_kv = rmsnorm(p["kv_norm"], down[..., :m.kv_lora])        # (B,T,lora)
    k_rope = apply_rope(down[..., None, m.kv_lora:], positions,
                        cfg.rope_theta)[:, :, 0]               # (B,T,rope)

    if cache is not None and T == 1:
        idx = cache["length"]
        cc = jax.lax.dynamic_update_slice(cache["c_kv"],
                                          c_kv.astype(cache["c_kv"].dtype),
                                          (0, idx, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"],
                                          k_rope.astype(cache["k_rope"].dtype),
                                          (0, idx, 0))
        new_cache = {"c_kv": cc, "k_rope": cr, "length": idx + 1}
        # absorbed path: q_nope pulled through k_up; output through v_up
        from repro.core.compiled_linear import dense_of
        k_up = dense_of(p["k_up"]).reshape(m.kv_lora, H, m.qk_nope)
        qa = jnp.einsum("bthn,lhn->bthl", q_nope.astype(jnp.float32),
                        k_up.astype(jnp.float32))              # (B,1,H,lora)
        s = (jnp.einsum("bthl,bsl->bhts", qa, cc.astype(jnp.float32))
             + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                          cr.astype(jnp.float32)))
        s = s / jnp.sqrt(m.qk_nope + m.qk_rope)
        valid = jnp.arange(cc.shape[1])[None, None, None, :] < idx + 1
        w = jax.nn.softmax(jnp.where(valid, s, NEG_INF), axis=-1)
        ctx = jnp.einsum("bhts,bsl->bthl", w, cc.astype(jnp.float32))
        v_up = dense_of(p["v_up"]).reshape(m.kv_lora, H, m.v_dim)
        o = jnp.einsum("bthl,lhv->bthv", ctx, v_up.astype(jnp.float32))
        out = apply_linear(p["o"], o.reshape(B, 1, H * m.v_dim).astype(x.dtype),
                           qat)
        return out, new_cache

    new_cache = None
    if cache is not None:  # prefill into latent cache
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0))
        new_cache = {"c_kv": cc, "k_rope": cr, "length": jnp.int32(T)}
    # expanded path (training / prefill)
    k_nope = apply_linear(p["k_up"], c_kv, qat).reshape(B, T, H, m.qk_nope)
    v = apply_linear(p["v_up"], c_kv, qat).reshape(B, T, H, m.v_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, T, H, m.qk_rope))],
        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = gqa_attention(qf, k, v, causal=True)
    return apply_linear(p["o"], o.reshape(B, T, H * m.v_dim), qat), new_cache


def mla_cache_spec(cfg, B, S_max, dtype=jnp.bfloat16):
    if dtype == jnp.int8:  # latent cache is already compressed; keep bf16
        dtype = jnp.bfloat16
    m = cfg.mla
    return {
        "c_kv": nn.Param(jnp.zeros((B, S_max, m.kv_lora), dtype),
                         ("batch", "kv_seq", None)),
        "k_rope": nn.Param(jnp.zeros((B, S_max, m.qk_rope), dtype),
                           ("batch", "kv_seq", None)),
        "length": nn.Param(jnp.zeros((), jnp.int32), ()),
    }

"""Generic LM assembly: builds every assigned architecture from ArchConfig.

Layers are grouped into a (prefix, periodic template x n_groups, suffix)
structure; the periodic part runs under jax.lax.scan with per-template-
position stacked parameters (keeps HLO size O(template) instead of
O(n_layers)) and jax.checkpoint for activation rematerialization.  The
same structure carries decode caches (KV / MLA-latent / SSM states).

Supports: dense GQA (smollm/stablelm/phi3), local-global sliding window
(gemma3), MLA + MoE (deepseek-v2-lite), pure MoE (olmoe), hybrid
attn/mamba/MoE (jamba), RWKV6, encoder-decoder (whisper, stubbed audio
frontend), M-RoPE VLM backbone (qwen2-vl, stubbed vision tower).
"""
from __future__ import annotations

import functools
from math import gcd

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import ArchConfig
from repro.core.compiled_linear import apply_linear
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed, embed_init, ffn, ffn_init, layernorm,
                                 layernorm_init, lm_head, lm_head_init,
                                 rmsnorm, rmsnorm_init, sinusoidal_positions)
from repro.distributed.sharding import shard


# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------

def _sig_key(sig):
    return (sig["kind"], bool(sig["moe"]), sig["attn_type"])


def group_layers(sigs):
    """-> (n_prefix, period, n_groups, n_suffix) covering the layer list."""
    n = len(sigs)
    keys = [_sig_key(s) for s in sigs]
    best = None
    for pre in range(0, 3):
        for suf in range(0, 3):
            m = n - pre - suf
            if m <= 0:
                continue
            for p in range(1, min(m, 8) + 1):
                if m % p:
                    continue
                mid = keys[pre:n - suf]
                if all(mid[i] == mid[i % p] for i in range(m)):
                    cand = (pre, p, m // p, suf)
                    # prefer fewer unrolled layers, then smaller period
                    score = (pre + suf, p)
                    if best is None or score < best[0]:
                        best = (score, cand)
                    break
    assert best is not None, "no periodic grouping found"
    return best[1]


# ---------------------------------------------------------------------------
# Single block (mixer + FFN/MoE)
# ---------------------------------------------------------------------------

def _norm_init(key, cfg, d=None):
    d = d or cfg.d_model
    return (rmsnorm_init(key, d) if cfg.norm == "rmsnorm"
            else layernorm_init(key, d))


def _norm(p, x, cfg):
    return (rmsnorm(p, x, cfg.norm_eps) if cfg.norm == "rmsnorm"
            else layernorm(p, x, cfg.norm_eps))


def block_init(key, cfg: ArchConfig, sig, cross=False):
    ks = jax.random.split(key, 8)
    p = {"ln1": _norm_init(ks[0], cfg)}
    if sig["kind"] == "attn":
        p["mixer"] = (attn.mla_init(ks[1], cfg) if cfg.mla
                      else attn.gqa_init(ks[1], cfg))
    elif sig["kind"] == "mamba":
        p["mixer"] = ssm_mod.mamba_init(ks[1], cfg)
    elif sig["kind"] == "rwkv":
        p["mixer"] = ssm_mod.rwkv6_init(ks[1], cfg)
    else:
        raise ValueError(sig)
    if cross:
        p["ln_x"] = _norm_init(ks[2], cfg)
        p["xattn"] = attn.gqa_init(ks[3], cfg)
    p["ln2"] = _norm_init(ks[4], cfg)
    if sig["moe"]:
        p["ffn"] = moe_mod.moe_init(ks[5], cfg)
    elif sig["kind"] == "rwkv":
        p["ffn"] = rwkv_cm_init(ks[5], cfg)
    else:
        d_ff = cfg.d_ff
        if cfg.first_layer_dense and sig["index"] == 0 and cfg.moe:
            d_ff = cfg.d_ff  # cfg.d_ff holds the dense-layer width
        p["ffn"] = ffn_init(ks[5], cfg.d_model, d_ff,
                            gated=cfg.act in ("silu", "gelu"))
    if cfg.post_block_norm:
        p["post_ln1"] = _norm_init(ks[6], cfg)
        p["post_ln2"] = _norm_init(ks[7], cfg)
    return p


def rwkv_cm_init(key, cfg):
    ks = jax.random.split(key, 4)
    d, dff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": nn.param(ks[0], (d,), ("embed",), scale=0.5),
        "mu_r": nn.param(ks[1], (d,), ("embed",), scale=0.5),
        "wk": nn.linear_param(ks[1], d, dff, ("embed", "ffn_in")),
        "wr": nn.linear_param(ks[2], d, d, ("embed", "embed_out")),
        "wv": nn.linear_param(ks[3], dff, d, ("ffn_in", "embed")),
    }


def rwkv_cm(p, x, state=None, qat=False):
    """RWKV channel-mix with token shift; returns (y, new_shift)."""
    xf = x
    if state is not None:
        prev = jnp.concatenate([state.astype(x.dtype), x[:, :-1]], axis=1)
    else:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    new_shift = x[:, -1:]
    xk = xf + (prev - xf) * p["mu_k"].astype(x.dtype)
    xr = xf + (prev - xf) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(apply_linear(p["wk"], xk, qat)))
    r = jax.nn.sigmoid(apply_linear(p["wr"], xr, qat))
    return r * apply_linear(p["wv"], k, qat), new_shift


def block_cache_init(cfg, sig, B, S_max, cross=False, kv_dtype=None):
    import jax.numpy as _jnp
    kv_dtype = kv_dtype or _jnp.bfloat16
    if sig["kind"] == "attn":
        c = (attn.mla_cache_spec(cfg, B, S_max, kv_dtype) if cfg.mla
             else attn.gqa_cache_spec(cfg, B, S_max, kv_dtype))
    elif sig["kind"] == "mamba":
        c = ssm_mod.mamba_state_spec(cfg, B)
    else:
        c = {"tm": ssm_mod.rwkv6_state_spec(cfg, B),
             "cm": nn.Param(jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16),
                            ("batch", None, "embed_s"))}
    return c


def block_apply(p, x, cfg, sig, positions, cache=None, cross_kv=None,
                qat=False, decode=False, causal=True):
    """Returns (x, new_cache, aux).

    cache semantics: None -> training (no state tracked); provided with
    decode=False -> prefill (state written from scratch); provided with
    decode=True -> single-step decode (state read + advanced).
    """
    aux = {"lb_loss": 0.0, "z_loss": 0.0, "dropped_frac": 0.0}
    h = _norm(p["ln1"], x, cfg)
    new_cache = None
    if sig["kind"] == "attn":
        window = cfg.window if sig["attn_type"] == "local" else None
        fwd = attn.mla_forward if cfg.mla else functools.partial(
            attn.gqa_forward, window=window, causal=causal)
        out, new_cache = fwd(p["mixer"], h, cfg, positions,
                             cache=cache, qat=qat)
    elif sig["kind"] == "mamba":
        out, st = ssm_mod.mamba_forward(
            p["mixer"], h, cfg, state=cache if decode else None, qat=qat)
        new_cache = st if cache is not None else None
    else:  # rwkv
        tm_state = cache["tm"] if (cache is not None and decode) else None
        out, tm_new = ssm_mod.rwkv6_forward(p["mixer"], h, cfg,
                                            state=tm_state, qat=qat)
    if cfg.post_block_norm:
        out = _norm(p["post_ln1"], out, cfg)
    x = x + out

    if "xattn" in p and cross_kv is not None:
        if isinstance(cross_kv, tuple):
            kv = cross_kv
        else:  # raw encoder states: project k/v here (training path)
            Bx, Te, _ = cross_kv.shape
            KVH, D = cfg.n_kv_heads, cfg.head_dim
            kv = (apply_linear(p["xattn"]["k"], cross_kv,
                               qat).reshape(Bx, Te, KVH, D),
                  apply_linear(p["xattn"]["v"], cross_kv,
                               qat).reshape(Bx, Te, KVH, D))
        hx = _norm(p["ln_x"], x, cfg)
        xo, _ = attn.gqa_forward(p["xattn"], hx, cfg, positions,
                                 causal=False, cross_kv=kv, qat=qat)
        x = x + xo

    h2 = _norm(p["ln2"], x, cfg)
    if sig["moe"]:
        y, aux = moe_mod.moe_forward(p["ffn"], h2, cfg, qat=qat)
    elif sig["kind"] == "rwkv":
        cm_state = cache["cm"] if (cache is not None and decode) else None
        y, cm_new = rwkv_cm(p["ffn"], h2, state=cm_state, qat=qat)
        if cache is not None:
            new_cache = {"tm": tm_new, "cm": cm_new}
    else:
        y = ffn(p["ffn"], h2, act=cfg.act, qat=qat)
    if cfg.post_block_norm:
        y = _norm(p["post_ln2"], y, cfg)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 12)
    params = {"embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
              "final_norm": _norm_init(ks[1], cfg)}
    if not cfg.tie_embeddings:
        params["head"] = lm_head_init(ks[2], cfg.d_model, cfg.vocab)
    if cfg.encoder_decoder:
        enc_sig = dict(kind="attn", moe=False, attn_type="global", index=0)
        params["enc_blocks"] = nn.vmap_init(
            lambda k: block_init(k, cfg, enc_sig), ks[3], cfg.n_enc_layers)
        params["enc_norm"] = _norm_init(ks[4], cfg)
        params["dec_blocks"] = nn.vmap_init(
            lambda k: block_init(k, cfg, enc_sig, cross=True), ks[5],
            cfg.n_layers)
        return params
    sigs = cfg.layer_sigs()
    pre, period, groups, suf = group_layers(sigs)
    params["prefix"] = [
        block_init(jax.random.fold_in(ks[6], i), cfg, sigs[i])
        for i in range(pre)]
    params["template"] = [
        nn.vmap_init(lambda k, j=j: block_init(k, cfg, sigs[pre + j]),
                     jax.random.fold_in(ks[7], j), groups)
        for j in range(period)]
    params["suffix"] = [
        block_init(jax.random.fold_in(ks[8], i), cfg,
                   sigs[pre + groups * period + i])
        for i in range(suf)]
    return params


def cache_init(cfg: ArchConfig, B: int, S_max: int, S_enc: int | None = None,
               kv_dtype=None):
    """Decode cache pytree (Param-boxed for sharding specs).

    S_enc: enc-dec cross k/v length (must equal the prefill frame count;
    defaults to 1500 = Whisper's 30 s post-conv frame budget).
    kv_dtype: jnp.int8 stores the attention KV cache quantized with
    per-(token, head) scales (SSPerf decode it-3).
    """
    pos = nn.Param(jnp.zeros((B,), jnp.int32), ("batch",))
    if cfg.encoder_decoder:
        KVH, D = cfg.n_kv_heads, cfg.head_dim
        Se = S_enc or 1500
        enc_sig = dict(kind="attn", moe=False, attn_type="global", index=0)
        dec = [block_cache_init(cfg, enc_sig, B, S_max, kv_dtype=kv_dtype)
               for _ in range(cfg.n_layers)]
        cross = [{"k": nn.Param(jnp.zeros((B, Se, KVH, D), jnp.bfloat16),
                                ("batch", "kv_seq", "heads_kv_sharded", None)),
                  "v": nn.Param(jnp.zeros((B, Se, KVH, D), jnp.bfloat16),
                                ("batch", "kv_seq", "heads_kv_sharded", None))}
                 for _ in range(cfg.n_layers)]
        return {"dec": _stack_caches(dec), "cross": _stack_caches(cross),
                "pos": pos}
    sigs = cfg.layer_sigs()
    pre, period, groups, suf = group_layers(sigs)
    out = {
        "prefix": [block_cache_init(cfg, sigs[i], B, S_max,
                                    kv_dtype=kv_dtype) for i in range(pre)],
        "template": [
            _stack_caches([block_cache_init(cfg, sigs[pre + j], B, S_max,
                                            kv_dtype=kv_dtype)
                           for _ in range(groups)])
            for j in range(period)],
        "suffix": [block_cache_init(cfg, sigs[pre + groups * period + i],
                                    B, S_max, kv_dtype=kv_dtype)
                   for i in range(suf)],
        "pos": pos,
    }
    return out


def _stack_caches(caches: list):
    return jax.tree.map(
        lambda *ps: nn.Param(jnp.stack([p.value for p in ps]),
                             ("layers",) + ps[0].axes, ps[0].kind),
        *caches, is_leaf=lambda x: isinstance(x, nn.Param))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _positions(cfg, batch, B, T, offset=None):
    if cfg.pos == "mrope":
        if "positions" in batch:
            return batch["positions"]
        base = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        if offset is not None:
            base = base + offset[:, None]
        return jnp.broadcast_to(base[None], (3, B, T))
    base = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if offset is not None:
        base = base + offset[:, None]
    return base


def _run_stack(params, x, cfg, sigs_info, positions, cache=None,
               cross_kv=None, qat=False, decode=False, causal=True,
               remat=True):
    """Prefix blocks, scanned template, suffix blocks."""
    pre, period, groups, suf = sigs_info["grouping"]
    sigs = sigs_info["sigs"]
    aux_sum = {"lb_loss": 0.0, "z_loss": 0.0, "dropped_frac": 0.0}
    new_cache = {} if cache is not None else None

    def run_one(p, x, sig, c):
        return block_apply(p, x, cfg, sig, positions, cache=c,
                           cross_kv=cross_kv, qat=qat, decode=decode,
                           causal=causal)

    for i in range(pre):
        c = cache["prefix"][i] if cache is not None else None
        x, nc, aux = run_one(params["prefix"][i], x, sigs[i], c)
        aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        if cache is not None:
            new_cache.setdefault("prefix", []).append(nc)

    def body(carry, xs):
        x, acc = carry
        newcs = []
        for j in range(period):
            c = xs["cache"][j] if cache is not None else None
            xj, nc, aux = run_one(xs["params"][j], x, sigs[pre + j], c)
            x = xj
            acc = {k: acc[k] + aux[k] for k in acc}
            newcs.append(nc if nc is not None else 0)
        return (x, acc), {"cache": newcs} if cache is not None else 0

    body_fn = jax.checkpoint(body) if remat else body
    xs = {"params": params["template"]}
    if cache is not None:
        xs["cache"] = cache["template"]
    if getattr(cfg, "unroll", False):
        carry, ys_list = (x, aux_sum), []
        for g in range(groups):
            xs_g = jax.tree.map(lambda a: a[g], xs)
            carry, y = body_fn(carry, xs_g)
            ys_list.append(y)
        (x, aux_sum) = carry
        ys = (jax.tree.map(lambda *a: jnp.stack(a), *ys_list)
              if cache is not None else 0)
    else:
        (x, aux_sum), ys = jax.lax.scan(body_fn, (x, aux_sum), xs)
    if cache is not None:
        new_cache["template"] = ys["cache"]

    for i in range(suf):
        li = pre + groups * period + i
        c = cache["suffix"][i] if cache is not None else None
        x, nc, aux = run_one(params["suffix"][i], x, sigs[li], c)
        aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        if cache is not None:
            new_cache.setdefault("suffix", []).append(nc)
    if cache is not None:
        new_cache.setdefault("prefix", [])
        new_cache.setdefault("suffix", [])
    return x, new_cache, aux_sum


def _grouping_info(cfg):
    sigs = cfg.layer_sigs()
    return {"sigs": sigs, "grouping": group_layers(sigs)}


def _logits(params, x, cfg, qat):
    x = _norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        return lm_head(None, x, tied_embed=params["embed"]["table"])
    return lm_head(params["head"], x, qat=qat)


def forward_train(params, batch, cfg: ArchConfig, qat=False):
    """-> (logits, aux).  batch: tokens/labels (+frames for enc-dec)."""
    if cfg.encoder_decoder:
        return _whisper_forward(params, batch, cfg, qat=qat)
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = embed(params["embed"], tokens).astype(jnp.bfloat16)
    if cfg.post_block_norm:  # gemma-style embed scaling
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq", None)
    positions = _positions(cfg, batch, B, T)
    info = _grouping_info(cfg)
    x, _, aux = _run_stack(params, x, cfg, info, positions, qat=qat,
                           remat=getattr(cfg, "remat", True))
    x = shard(x, "batch", "seq", None)
    return _logits(params, x, cfg, qat), aux


def _whisper_forward(params, batch, cfg, qat=False, cache=None):
    frames = batch["frames"]
    B = frames.shape[0]
    Te = frames.shape[1]
    pe = jnp.asarray(sinusoidal_positions(Te, cfg.d_model), frames.dtype)
    h = frames + pe[None]
    enc_sig = dict(kind="attn", moe=False, attn_type="global", index=0)

    def enc_body(x, p):
        x, _, _ = block_apply(p, x, cfg, enc_sig, None, causal=False, qat=qat)
        return x, 0

    enc_fn = jax.checkpoint(enc_body)
    h, _ = jax.lax.scan(enc_fn, h, params["enc_blocks"])
    enc_out = _norm(params["enc_norm"], h, cfg)

    tokens = batch["tokens"]
    Td = tokens.shape[1]
    x = embed(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + jnp.asarray(sinusoidal_positions(Td, cfg.d_model), x.dtype)[None]

    def dec_body(carry, p):
        x = carry
        x, _, _ = block_apply(p, x, cfg, enc_sig, None, cross_kv=enc_out,
                              qat=qat, causal=True)
        return x, 0

    dec_fn = jax.checkpoint(dec_body)
    x, _ = jax.lax.scan(dec_fn, x, params["dec_blocks"])
    aux = {"lb_loss": 0.0, "z_loss": 0.0, "dropped_frac": 0.0}
    return _logits(params, x, cfg, qat), aux


def forward_prefill(params, batch, cfg: ArchConfig, cache):
    """Prompt ingestion: returns (last-token logits, filled cache).

    ``batch`` may carry a ``length`` (B,) int32 of true prompt lengths
    alongside ``tokens`` end-padded to a bucketed width (the serving
    engine pads to powers of two so one compiled program serves a whole
    length bucket).  Causal attention makes the pad suffix invisible to
    every real position, so the bucketed prefill is exact when all rows
    share one length — the engine's path, which prefills one request
    (B=1) at a time: logits are gathered at position length-1 and every
    cache length counter is rewound to the true length, which decode
    masking then honors.  The attention caches keep a batch-shared
    SCALAR length counter (per-row lengths live in ``pos``), so a B>1
    call with heterogeneous lengths rewinds to max(length) and shorter
    rows would still see their pad KV — don't do that.  Likewise only
    attention mixers are rewindable: pad tokens advance mamba/rwkv
    recurrent scan states, so recurrent stacks must prefill unpadded
    (the engine gates bucketing on attention-only ``layer_sigs``).
    """
    if cfg.encoder_decoder:
        return _whisper_prefill(params, batch, cfg, cache)
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = embed(params["embed"], tokens).astype(jnp.bfloat16)
    if cfg.post_block_norm:
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq", None)
    positions = _positions(cfg, batch, B, T)
    info = _grouping_info(cfg)
    x, new_cache, _ = _run_stack(params, x, cfg, info, positions,
                                 cache=cache, decode=False)
    length = batch.get("length")
    if length is None:
        new_cache["pos"] = jnp.full((B,), T, jnp.int32)
        logits = _logits(params, x[:, -1:], cfg, qat=False)
    else:
        length = jnp.asarray(length, jnp.int32).reshape(B)
        new_cache["pos"] = length
        new_cache = _rewind_lengths(new_cache, jnp.max(length))
        idx = jnp.broadcast_to((length - 1)[:, None, None],
                               (B, 1, x.shape[-1]))
        logits = _logits(params, jnp.take_along_axis(x, idx, axis=1),
                         cfg, qat=False)
    return logits, new_cache


def _rewind_lengths(cache, length):
    """Clamp every attention-cache ``length`` counter (a batch-shared
    scalar, see attention cache specs) to the true prompt length: a
    bucketed prefill writes pad-token KV at positions >= length, and
    decode masks keys by ``pos < length``, so the clamp makes the pad
    rows unreachable (the next decode step overwrites the first one).
    Exact for uniform-length batches — the engine's B=1 prefill."""
    def fix(path, v):
        if getattr(path[-1], "key", None) == "length":
            return jnp.minimum(v, length)
        return v
    return jax.tree_util.tree_map_with_path(fix, cache)


def forward_decode(params, batch, cfg: ArchConfig, cache):
    """One decode step: token (B, 1) + cache -> (logits, cache)."""
    if cfg.encoder_decoder:
        return _whisper_decode(params, batch, cfg, cache)
    token = batch["token"]
    B = token.shape[0]
    x = embed(params["embed"], token).astype(jnp.bfloat16)
    if cfg.post_block_norm:
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    positions = _positions(cfg, batch, B, 1, offset=cache["pos"])
    info = _grouping_info(cfg)
    x, new_cache, _ = _run_stack(params, x, cfg, info, positions,
                                 cache=cache, decode=True)
    new_cache["pos"] = cache["pos"] + 1
    return _logits(params, x, cfg, qat=False), new_cache


def _whisper_prefill(params, batch, cfg, cache):
    frames = batch["frames"]
    B, Te, _ = frames.shape
    pe = jnp.asarray(sinusoidal_positions(Te, cfg.d_model), frames.dtype)
    h = frames + pe[None]
    enc_sig = dict(kind="attn", moe=False, attn_type="global", index=0)

    def enc_body(x, p):
        x, _, _ = block_apply(p, x, cfg, enc_sig, None, causal=False)
        return x, 0

    h, _ = jax.lax.scan(jax.checkpoint(enc_body), h, params["enc_blocks"])
    enc_out = _norm(params["enc_norm"], h, cfg)

    # fill cross k/v cache per decoder layer (cache sized to enc length)
    KVH, D = cfg.n_kv_heads, cfg.head_dim
    Sc = cache["cross"]["k"].shape[2]
    assert Sc == Te, f"cross cache length {Sc} != encoder frames {Te}"

    def cross_kv_of(pdec):
        k = apply_linear(pdec["xattn"]["k"], enc_out).reshape(B, Te, KVH, D)
        v = apply_linear(pdec["xattn"]["v"], enc_out).reshape(B, Te, KVH, D)
        return k, v

    ks, vs = jax.vmap(cross_kv_of)(params["dec_blocks"])       # (L, B, Te,..)
    new_cross = {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16)}

    # run decoder prompt through self-attn caches
    tokens = batch["tokens"]
    Td = tokens.shape[1]
    x = embed(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + jnp.asarray(sinusoidal_positions(Td, cfg.d_model), x.dtype)[None]

    def dec_body(carry, xs):
        x = carry
        x, nc, _ = block_apply(xs["p"], x, cfg, enc_sig, None,
                               cache=xs["c"], cross_kv=(xs["ck"], xs["cv"]),
                               causal=True, decode=False)
        return x, nc

    x, new_dec = jax.lax.scan(
        jax.checkpoint(dec_body), x,
        {"p": params["dec_blocks"], "c": cache["dec"], "ck": ks, "cv": vs})
    new_cache = {"dec": new_dec, "cross": new_cross,
                 "pos": jnp.full((B,), Td, jnp.int32)}
    return _logits(params, x[:, -1:], cfg, qat=False), new_cache


def _whisper_decode(params, batch, cfg, cache):
    token = batch["token"]
    B = token.shape[0]
    x = embed(params["embed"], token).astype(jnp.bfloat16)
    Td_max = cache["dec"]["k"].shape[2]
    pos_table = jnp.asarray(sinusoidal_positions(Td_max, cfg.d_model), x.dtype)
    x = x + pos_table[cache["pos"][0]][None, None]
    enc_sig = dict(kind="attn", moe=False, attn_type="global", index=0)

    def dec_body(carry, xs):
        x = carry
        x, nc, _ = block_apply(xs["p"], x, cfg, enc_sig, None,
                               cache=xs["c"], cross_kv=(xs["ck"], xs["cv"]),
                               causal=True, decode=True)
        return x, nc

    x, new_dec = jax.lax.scan(
        dec_body, x,
        {"p": params["dec_blocks"], "c": cache["dec"],
         "ck": cache["cross"]["k"], "cv": cache["cross"]["v"]})
    new_cache = {"dec": new_dec, "cross": cache["cross"],
                 "pos": cache["pos"] + 1}
    return _logits(params, x, cfg, qat=False), new_cache


def loss_fn(logits, labels, aux=None, z_coef=1e-4, lb_coef=1e-2):
    """Causal-LM cross entropy (next token) + MoE aux losses."""
    logits = logits[:, :-1].astype(jnp.float32)
    targets = labels[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    total = ce
    metrics = {"ce": ce}
    if aux is not None:
        total = total + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
        metrics.update(aux)
    return total, metrics

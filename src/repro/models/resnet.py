"""ResNet50 — the paper's own network, as a Compiled NN in JAX.

Residual blocks follow the paper's Fig 1 decomposition: the Kernel is the
convolution MACs and the Non-Kernel is everything else — bias add,
per-channel scaling (folded BatchNorm), ReLU, rounding to 8 bits, and the
shortcut add (the last Collector in each block adds the shortcut,
SS II-D.4).

Two forward paths (DESIGN.md §4):

* **dense** (training / pre-refactor baseline): im2col patches through
  ``apply_linear`` with separate XLA Collector ops — kept verbatim as the
  reference the compiled path is validated against.
* **compiled**: the model is a conv-DAG over quantization-domain edges
  (models/graph.py, DESIGN.md §12).  ``resnet_graph`` builds the graph —
  stem conv + maxpool, bottleneck blocks whose shortcut rides the last
  conv's Collector epilogue, classifier head — and ``graph.compile_graph``
  cuts it at articulation edges into pipeline units, each a pure function
  of its own param subtree with producer-side quantization, so every unit
  edge is an ``(int8, scale[row])`` pair and the pipeline-parallel engine
  (serving/pipeline.py) slices the unit list into per-device stages
  bit-identically (DESIGN.md §7).  Weights are constant int8 codes in the
  kernels' spatial-major tap layout carrying their (k, stride, c_in)
  geometry; each conv is ONE fused row-strip-tiled implicit-GEMM launch
  (``compiled_linear.apply_conv``) with the whole Collector in the
  epilogue.  In ``sparse_cfmm`` mode the weight leaves are bitmap-packed
  and the same seam dispatches to the bitmap-native sparse conv kernel
  (``kernels/conv_sparse.py``) — this file needs no sparse-specific code;
  the leaf's storage keys select the dataflow.

Inference-focused (the paper compiles post-training parameters); a width
multiplier supports reduced smoke configs, and the bottleneck
``expansion`` is a config field (Table I's networks all use 4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.compiled_linear import apply_conv, apply_linear
from repro.core.fpga_model import ConvLayerSpec
from repro.models.graph import Graph, Node, PipelineUnit, compile_graph

__all__ = [
    "RESNET50_STAGES", "ResNetConfig", "table1", "conv_blocks_for",
    "resnet50_conv_blocks", "init", "apply", "resnet_graph",
    "compiled_units", "PipelineUnit",
]

# (blocks, mid_channels, out_channels, feature hw) per stage — Table I.
RESNET50_STAGES = [
    ("conv2_x", 3, 64, 256, 56),
    ("conv3_x", 4, 128, 512, 28),
    ("conv4_x", 6, 256, 1024, 14),
    ("conv5_x", 3, 512, 2048, 7),
]


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    width_mult: float = 1.0
    num_classes: int = 1000
    in_hw: int = 224
    expansion: int = 4          # bottleneck out/mid ratio (Table I: 4)

    def __post_init__(self):
        if self.expansion < 1:
            raise ValueError(
                f"expansion must be a positive integer, got {self.expansion}")

    def stage(self, i):
        name, blocks, mid, _, hw = RESNET50_STAGES[i]
        w = self.width_mult
        return (name, blocks, max(8, int(mid * w)),
                max(8, int(mid * self.expansion * w)), hw)

    # The serving stack (pipeline engine, frontend, partition planner)
    # drives any model through this trio — see mobilenet_v2.py/repvgg.py
    # for the other zoo members.
    def graph(self) -> Graph:
        return resnet_graph(self)

    def init(self, key):
        return init(key, self)

    def apply(self, params, x):
        return apply(params, x, self)


def table1(expansion: int = 4) -> dict:
    """Reproduce Table I exactly from the architecture definition.

    Table I's per-stage parameter algebra (in·mid + 9·mid² + mid·out with
    in = out = expansion·mid) is only valid when the bottleneck expansion
    matches the stage table's channel counts — anything else raises
    rather than silently reporting wrong MAC/param counts.
    """
    rows = {}
    for name, _, mid, out, hw in RESNET50_STAGES:
        if out != expansion * mid:
            raise ValueError(
                f"table1: stage {name} has out={out} but expansion*mid = "
                f"{expansion}*{mid} = {expansion * mid}; Table I's "
                "param/MAC algebra assumes out == expansion*mid")
        in_ch = out  # mid-stage block input = stage output channels
        params = in_ch * mid + mid * mid * 9 + mid * out
        macs = params * hw * hw
        rows[name] = dict(
            channel_count=f"{mid}/{out}",
            hw=f"{hw}x{hw}",
            param_count_k=round(params / 1000),
            total_macs_m=round(macs / 1e6),
            mac_per_param=hw * hw,
        )
    return rows


def conv_blocks_for(cfg: ResNetConfig) -> list[list[ConvLayerSpec]]:
    """All conv layers grouped by block for an arbitrary config — block 0
    is the stem, then residual blocks in dataflow order.  Feature sizes
    follow the model's SAME/stride chain from ``cfg.in_hw``, so the
    analytic specs (and their ``out_bytes`` link counts) describe exactly
    the network the serving pipeline executes."""
    w0 = max(8, int(64 * cfg.width_mult))
    h = -(-cfg.in_hw // 2)                       # stride-2 stem conv
    blocks = [[ConvLayerSpec("conv1", 3, w0, 7, h, stride=2)]]
    h = -(-h // 2)                               # stride-2 maxpool
    in_ch = w0
    for i in range(4):
        name, n_blocks, mid, out, _ = cfg.stage(i)
        if name != "conv2_x":
            h = -(-h // 2)                       # stage-entry stride
        for b in range(n_blocks):
            layers = [
                ConvLayerSpec(f"{name}_{b+1}_a", in_ch, mid, 1, h),
                ConvLayerSpec(f"{name}_{b+1}_b", mid, mid, 3, h),
                ConvLayerSpec(f"{name}_{b+1}_c", mid, out, 1, h),
            ]
            if b == 0:  # projection shortcut
                layers.append(ConvLayerSpec(f"{name}_{b+1}_sc", in_ch, out, 1, h))
            blocks.append(layers)
            in_ch = out
    return blocks


def resnet50_conv_blocks() -> list[list[ConvLayerSpec]]:
    """All conv layers grouped by residual block (for the Fig 7 planner)."""
    return conv_blocks_for(ResNetConfig())


# ---------------------------------------------------------------------------
# Functional model
# ---------------------------------------------------------------------------

def _conv_init(key, c_in, c_out, k, stride=1):
    return {
        "w": nn.conv_param(key, c_in, c_out, k, stride,
                           ("conv_in", "conv_out")),
        "scale": nn.param(key, (c_out,), ("conv_out",), init="ones"),
        "bias": nn.param(key, (c_out,), ("conv_out",), init="zeros"),
    }


def _conv_apply(p, x, k, stride=1, relu=True, shortcut=None):
    """Dense path: im2col conv + separate NK collector ops (bias, scale/BN,
    shortcut, ReLU).  This is the pre-refactor baseline the fused compiled
    path is validated against."""
    if k > 1:
        patches = jax.lax.conv_general_dilated_patches(
            x, (k, k), (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    else:
        patches = x[:, ::stride, ::stride, :]
    y = apply_linear(p["w"], patches)
    y = y * p["scale"] + p["bias"]
    if shortcut is not None:
        y = y + shortcut
    return jax.nn.relu(y) if relu else y


def _conv_q(p, x_q, s_x, **kw):
    """Compiled path: one fused implicit-GEMM launch; geometry rides the
    weight, the Collector (scale/BN, bias, shortcut, ReLU, 8-bit rounding)
    rides the kernel epilogue."""
    return apply_conv(p["w"], x_q, s_x, gamma=p["scale"], beta=p["bias"],
                      **kw)


def _block_stride(name: str, b: int) -> int:
    return 2 if (b == 0 and name != "conv2_x") else 1


def init(key, cfg: ResNetConfig):
    keys = iter(jax.random.split(key, 64))
    params = {"stem": _conv_init(next(keys), 3, max(8, int(64 * cfg.width_mult)),
                                 7, stride=2)}
    in_ch = max(8, int(64 * cfg.width_mult))
    for i in range(4):
        name, n_blocks, mid, out, hw = cfg.stage(i)
        stage = []
        for b in range(n_blocks):
            stride = _block_stride(name, b)
            blk = {
                "a": _conv_init(next(keys), in_ch, mid, 1, stride=stride),
                "b": _conv_init(next(keys), mid, mid, 3),
                "c": _conv_init(next(keys), mid, out, 1),
            }
            if b == 0:
                blk["sc"] = _conv_init(next(keys), in_ch, out, 1,
                                       stride=stride)
            stage.append(blk)
            in_ch = out
        params[name] = stage
    params["head"] = {"w": nn.linear_param(next(keys), in_ch, cfg.num_classes,
                                           ("embed", "classes"))}
    return params


# ---------------------------------------------------------------------------
# Graph (compiled path)
# ---------------------------------------------------------------------------

def resnet_graph(cfg: ResNetConfig) -> Graph:
    """ResNet50 as a conv-DAG (models/graph.py): the stem unit (quant →
    7x7/s2 conv → maxpool → quant), one unit per bottleneck block — the
    projection (b==0) or identity-dequant shortcut feeding the c-conv's
    Collector epilogue, a/b convs emitting int8 in-block (quant_out), a
    producer-side quant on the block edge — and the classifier head.

    The graph's articulation cuts land exactly on the stem/block/head
    boundaries the hand-rolled ``compiled_units`` used, so stage plans,
    unit names ("stem", "conv2_x_1", ..., "head"), and sparsity aux keys
    ("stem", "conv2_x_1/a", ...) are unchanged — and the compiled forward
    is bit-identical to the pre-graph path (tested).
    """
    w0 = max(8, int(64 * cfg.width_mult))
    nodes = [
        Node("image", "input"),
        Node("stem_in", "quant", ("image",), unit="stem"),
        Node("stem", "conv", ("stem_in",), path=("stem",), k=7, stride=2,
             c_in=3, c_out=w0),
        Node("stem_pool", "pool", ("stem",), k=3, stride=2),
        Node("stem_q", "quant", ("stem_pool",)),
    ]
    prev, in_ch = "stem_q", w0
    for i in range(4):
        name, n_blocks, mid, out, _ = cfg.stage(i)
        for b in range(n_blocks):
            u = f"{name}_{b+1}"
            stride = _block_stride(name, b)
            if b == 0:                       # projection shortcut (no ReLU)
                sc = f"{u}/sc"
                nodes.append(Node(sc, "conv", (prev,), path=(name, b, "sc"),
                                  k=1, stride=stride, c_in=in_ch, c_out=out,
                                  relu=False, unit=u))
            else:                            # identity: dequant the block input
                sc = f"{u}/id"
                nodes.append(Node(sc, "dequant", (prev,), unit=u))
            nodes.append(Node(f"{u}/a", "conv", (prev,), path=(name, b, "a"),
                              k=1, stride=stride, c_in=in_ch, c_out=mid,
                              quant_out=True))
            nodes.append(Node(f"{u}/b", "conv", (f"{u}/a",),
                              path=(name, b, "b"), k=3, c_in=mid, c_out=mid,
                              quant_out=True))
            nodes.append(Node(f"{u}/c", "conv", (f"{u}/b",),
                              path=(name, b, "c"), k=1, c_in=mid, c_out=out,
                              shortcut=sc))
            nodes.append(Node(f"{u}/q", "quant", (f"{u}/c",)))
            prev, in_ch = f"{u}/q", out
    nodes.append(Node("head", "head", (prev,), path=("head",)))
    return Graph("resnet50", tuple(nodes), cfg.in_hw, 3, cfg.num_classes)


def compiled_units(params, cfg: ResNetConfig,
                   sparsity_groups: int | None = None) -> list:
    """The compiled forward as an ordered list of pipeline units: the stem
    (conv + maxpool), each residual block, and the classifier head — now a
    thin wrapper over the DAG-general ``graph.compile_graph``.

    ``sparsity_groups`` opts every ReLU-output conv into activation-
    sparsity profiling at that coarse_in group size: unit fns then
    return ``(carry, {layer: zero-count aux})`` instead of a bare carry
    (obs/sparsity.py aggregates).  Carries are bit-identical either way.
    """
    return compile_graph(resnet_graph(cfg), params, sparsity_groups)


def _apply_compiled(params, x, cfg: ResNetConfig):
    """Compiled serving path: fused implicit-GEMM convs + the quantization-
    domain pass — one ``act_quant`` per block, int8 activations between the
    a/b/c convs AND on every block edge (producer-side quantization: each
    unit emits ``(int8, scale[row])``, so slicing the unit list into
    pipeline stages moves only 8-bit feature maps and cannot change the
    math).  Quantization domains are PER ROW (per image): every scale on
    every edge is an ``(N,)`` vector reduced over H·W·C only, so each
    image's entire forward is independent of its batch neighbours and any
    packing of rows into microbatches is bit-identical (DESIGN.md §9).
    The identity shortcut consumes the quantized block input — the FPGA's
    shortcut reads the same 8-bit inter-layer map (paper SS II-D.4).
    """
    carry = x
    for u in compiled_units(params, cfg):
        carry = u.fn(u.params, carry)
    return carry


def apply(params, x, cfg: ResNetConfig):
    """x: (B, H, W, 3) -> logits (B, num_classes)."""
    if isinstance(params["stem"]["w"], dict):      # compiled constant params
        return _apply_compiled(params, x, cfg)
    h = _conv_apply(params["stem"], x, 7, stride=2)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for i in range(4):
        name, n_blocks, mid, out, hw = cfg.stage(i)
        for b, blk in enumerate(params[name]):
            stride = _block_stride(name, b)
            sc = (_conv_apply(blk["sc"], h, 1, stride, relu=False)
                  if "sc" in blk else h)
            y = _conv_apply(blk["a"], h, 1, stride)
            y = _conv_apply(blk["b"], y, 3)
            h = _conv_apply(blk["c"], y, 1, relu=True, shortcut=sc)
    pooled = jnp.mean(h, axis=(1, 2))
    return apply_linear(params["head"]["w"], pooled)

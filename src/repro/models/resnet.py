"""ResNet50 — the paper's own network, as a Compiled NN in JAX.

Residual blocks follow the paper's Fig 1 decomposition: the Kernel is the
convolution MACs and the Non-Kernel is everything else — bias add,
per-channel scaling (folded BatchNorm), ReLU, rounding to 8 bits, and the
shortcut add (the last Collector in each block adds the shortcut,
SS II-D.4).

Two forward paths (DESIGN.md §4):

* **dense** (training / pre-refactor baseline): im2col patches through
  ``apply_linear`` with separate XLA Collector ops — kept verbatim as the
  reference the compiled path is validated against.
* **compiled**: weights are constant int8 codes stored in the kernels'
  spatial-major tap layout and carrying their (k, stride, c_in) geometry;
  each conv is ONE fused row-strip-tiled implicit-GEMM launch
  (``compiled_linear.apply_conv``) with the whole Collector in the
  epilogue — the strip planner (kernels/tiling.py) bounds per-cell VMEM
  so the path scales past ResNet50 geometry (the 224x224 stem tiles;
  7x7 conv5_x maps stay a single strip) — and residual blocks run a
  quantization-domain pass: one ``act_quant`` per block, then
  activations stay int8 between the a/b/c convs instead of per-conv f32
  requant round-trips.  The compiled forward is factored into
  ``compiled_units`` — stem / residual blocks / head, each a pure
  function of its own param subtree with producer-side quantization, so
  every unit edge is an ``(int8, scale)`` pair and the pipeline-parallel
  engine (serving/pipeline.py) slices the unit list into per-device
  stages bit-identically (DESIGN.md §7) — the replicated front-end
  (serving/frontend.py, DESIGN.md §8) reuses the same units unchanged:
  replication happens at the engine layer, never in the model.  In
  ``sparse_cfmm`` mode the weight leaves are bitmap-packed and the same
  seam dispatches to the bitmap-native sparse conv kernel
  (``kernels/conv_sparse.py``) — this file needs no sparse-specific code;
  the leaf's storage keys select the dataflow.

Inference-focused (the paper compiles post-training parameters); a width
multiplier supports reduced smoke configs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.compiled_linear import act_quant, apply_conv, apply_linear
from repro.core.fpga_model import ConvLayerSpec

# (blocks, mid_channels, out_channels, feature hw) per stage — Table I.
RESNET50_STAGES = [
    ("conv2_x", 3, 64, 256, 56),
    ("conv3_x", 4, 128, 512, 28),
    ("conv4_x", 6, 256, 1024, 14),
    ("conv5_x", 3, 512, 2048, 7),
]


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    width_mult: float = 1.0
    num_classes: int = 1000
    in_hw: int = 224

    def stage(self, i):
        name, blocks, mid, out, hw = RESNET50_STAGES[i]
        w = self.width_mult
        return name, blocks, max(8, int(mid * w)), max(8, int(out * w)), hw


def table1() -> dict:
    """Reproduce Table I exactly from the architecture definition."""
    rows = {}
    for name, _, mid, out, hw in RESNET50_STAGES:
        in_ch = out  # mid-stage block input = stage output channels
        params = in_ch * mid + mid * mid * 9 + mid * out
        macs = params * hw * hw
        rows[name] = dict(
            channel_count=f"{mid}/{out}",
            hw=f"{hw}x{hw}",
            param_count_k=round(params / 1000),
            total_macs_m=round(macs / 1e6),
            mac_per_param=hw * hw,
        )
    return rows


def conv_blocks_for(cfg: ResNetConfig) -> list[list[ConvLayerSpec]]:
    """All conv layers grouped by block for an arbitrary config — block 0
    is the stem, then residual blocks in dataflow order.  Feature sizes
    follow the model's SAME/stride chain from ``cfg.in_hw``, so the
    analytic specs (and their ``out_bytes`` link counts) describe exactly
    the network the serving pipeline executes."""
    w0 = max(8, int(64 * cfg.width_mult))
    h = -(-cfg.in_hw // 2)                       # stride-2 stem conv
    blocks = [[ConvLayerSpec("conv1", 3, w0, 7, h, stride=2)]]
    h = -(-h // 2)                               # stride-2 maxpool
    in_ch = w0
    for i in range(4):
        name, n_blocks, mid, out, _ = cfg.stage(i)
        if name != "conv2_x":
            h = -(-h // 2)                       # stage-entry stride
        for b in range(n_blocks):
            layers = [
                ConvLayerSpec(f"{name}_{b+1}_a", in_ch, mid, 1, h),
                ConvLayerSpec(f"{name}_{b+1}_b", mid, mid, 3, h),
                ConvLayerSpec(f"{name}_{b+1}_c", mid, out, 1, h),
            ]
            if b == 0:  # projection shortcut
                layers.append(ConvLayerSpec(f"{name}_{b+1}_sc", in_ch, out, 1, h))
            blocks.append(layers)
            in_ch = out
    return blocks


def resnet50_conv_blocks() -> list[list[ConvLayerSpec]]:
    """All conv layers grouped by residual block (for the Fig 7 planner)."""
    return conv_blocks_for(ResNetConfig())


# ---------------------------------------------------------------------------
# Functional model
# ---------------------------------------------------------------------------

def _conv_init(key, c_in, c_out, k, stride=1):
    return {
        "w": nn.conv_param(key, c_in, c_out, k, stride,
                           ("conv_in", "conv_out")),
        "scale": nn.param(key, (c_out,), ("conv_out",), init="ones"),
        "bias": nn.param(key, (c_out,), ("conv_out",), init="zeros"),
    }


def _conv_apply(p, x, k, stride=1, relu=True, shortcut=None):
    """Dense path: im2col conv + separate NK collector ops (bias, scale/BN,
    shortcut, ReLU).  This is the pre-refactor baseline the fused compiled
    path is validated against."""
    if k > 1:
        patches = jax.lax.conv_general_dilated_patches(
            x, (k, k), (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    else:
        patches = x[:, ::stride, ::stride, :]
    y = apply_linear(p["w"], patches)
    y = y * p["scale"] + p["bias"]
    if shortcut is not None:
        y = y + shortcut
    return jax.nn.relu(y) if relu else y


def _conv_q(p, x_q, s_x, **kw):
    """Compiled path: one fused implicit-GEMM launch; geometry rides the
    weight, the Collector (scale/BN, bias, shortcut, ReLU, 8-bit rounding)
    rides the kernel epilogue."""
    return apply_conv(p["w"], x_q, s_x, gamma=p["scale"], beta=p["bias"],
                      **kw)


def _block_stride(name: str, b: int) -> int:
    return 2 if (b == 0 and name != "conv2_x") else 1


def init(key, cfg: ResNetConfig):
    keys = iter(jax.random.split(key, 64))
    params = {"stem": _conv_init(next(keys), 3, max(8, int(64 * cfg.width_mult)),
                                 7, stride=2)}
    in_ch = max(8, int(64 * cfg.width_mult))
    for i in range(4):
        name, n_blocks, mid, out, hw = cfg.stage(i)
        stage = []
        for b in range(n_blocks):
            stride = _block_stride(name, b)
            blk = {
                "a": _conv_init(next(keys), in_ch, mid, 1, stride=stride),
                "b": _conv_init(next(keys), mid, mid, 3),
                "c": _conv_init(next(keys), mid, out, 1),
            }
            if b == 0:
                blk["sc"] = _conv_init(next(keys), in_ch, out, 1,
                                       stride=stride)
            stage.append(blk)
            in_ch = out
        params[name] = stage
    params["head"] = {"w": nn.linear_param(next(keys), in_ch, cfg.num_classes,
                                           ("embed", "classes"))}
    return params


@dataclasses.dataclass(frozen=True)
class PipelineUnit:
    """One schedulable unit of the compiled forward.

    ``fn(params, carry) -> carry`` is a pure function of the unit's OWN
    param subtree (``params`` here), so a pipeline stage holds exactly its
    units' constant weights and nothing else — the paper's persistent
    per-chip network.  Every edge between units is the quantization-domain
    pair ``(int8 activations, f32 scale[row])`` — the 8-bit inter-chip
    link, with one independent scale PER IMAGE (per-row domains,
    DESIGN.md §9) so serving may pack rows from different requests into
    one microbatch without any row's bits depending on its neighbours —
    except the f32 image into the stem and the f32 logits out of the head.
    ``block_id`` indexes ``conv_blocks_for``'s block list (stem = 0) so
    ``partition.StagePlan``s map 1:1 onto units; the head rides the last
    stage (``block_id`` -1).
    """

    name: str
    block_id: int
    params: dict
    fn: object


def _row_scale(s):
    """Broadcast a per-row ``(N,)`` scale (or a scalar) over NHWC values."""
    return jnp.asarray(s).reshape((-1,) + (1,) * 3)


def _stem_unit(p, x):
    x_q, s = act_quant(x, per_row=True)
    h = _conv_q(p, x_q, s, relu=True)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    return act_quant(h, per_row=True)


def _block_unit(p, carry):
    h_q, s_h = carry
    sc = (_conv_q(p["sc"], h_q, s_h, relu=False) if "sc" in p
          else h_q.astype(jnp.float32) * _row_scale(s_h))
    a_q, s_a = _conv_q(p["a"], h_q, s_h, quant_out=True)
    b_q, s_b = _conv_q(p["b"], a_q, s_a, quant_out=True)
    h = _conv_q(p["c"], b_q, s_b, shortcut=sc, relu=True)
    return act_quant(h, per_row=True)


def _head_unit(p, carry):
    h_q, s_h = carry
    pooled = jnp.mean(h_q.astype(jnp.float32) * _row_scale(s_h),
                      axis=(1, 2))
    # per_row: the head's input quantization must not couple rows either,
    # or a request's logits would depend on its microbatch neighbours
    return apply_linear(p["w"], pooled, per_row=True)


def _stem_unit_profiled(g):
    """Sparsity-profiled stem: same math, plus the post-ReLU zero-count
    aux of the stem conv.  Profiled unit fns return ``(carry, aux)``;
    the zero counts are observation-only so the carry is bit-identical
    to the unprofiled unit's (tested)."""
    def fn(p, x):
        x_q, s = act_quant(x, per_row=True)
        h, zc = _conv_q(p, x_q, s, relu=True, zero_count=g)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                  (1, 2, 2, 1), "SAME")
        return act_quant(h, per_row=True), {"stem": zc}
    return fn


def _block_unit_profiled(name, g):
    """Sparsity-profiled residual block: zero counts for the three
    ReLU-output convs (a, b, and the post-shortcut c).  The projection
    shortcut has no ReLU — its output isn't a post-ReLU sparsity
    candidate — so it stays unprofiled."""
    def fn(p, carry):
        h_q, s_h = carry
        sc = (_conv_q(p["sc"], h_q, s_h, relu=False) if "sc" in p
              else h_q.astype(jnp.float32) * _row_scale(s_h))
        a_q, s_a, zc_a = _conv_q(p["a"], h_q, s_h, quant_out=True,
                                 zero_count=g)
        b_q, s_b, zc_b = _conv_q(p["b"], a_q, s_a, quant_out=True,
                                 zero_count=g)
        h, zc_c = _conv_q(p["c"], b_q, s_b, shortcut=sc, relu=True,
                          zero_count=g)
        return act_quant(h, per_row=True), {f"{name}/a": zc_a,
                                            f"{name}/b": zc_b,
                                            f"{name}/c": zc_c}
    return fn


def _head_unit_profiled(p, carry):
    return _head_unit(p, carry), {}    # no conv, nothing to profile


def compiled_units(params, cfg: ResNetConfig,
                   sparsity_groups: int | None = None) -> list:
    """The compiled forward as an ordered list of pipeline units: the stem
    (conv + maxpool), each residual block, and the classifier head.

    ``sparsity_groups`` opts every ReLU-output conv into activation-
    sparsity profiling at that coarse_in group size: unit fns then
    return ``(carry, {layer: zero-count aux})`` instead of a bare carry
    (obs/sparsity.py aggregates).  Carries are bit-identical either way.
    """
    g = sparsity_groups
    units = [PipelineUnit("stem", 0, params["stem"],
                          _stem_unit if g is None else _stem_unit_profiled(g))]
    bid = 1
    for i in range(4):
        name = cfg.stage(i)[0]
        for b, blk in enumerate(params[name]):
            uname = f"{name}_{b+1}"
            units.append(PipelineUnit(
                uname, bid, blk,
                _block_unit if g is None else _block_unit_profiled(uname, g)))
            bid += 1
    units.append(PipelineUnit(
        "head", -1, params["head"],
        _head_unit if g is None else _head_unit_profiled))
    return units


def _apply_compiled(params, x, cfg: ResNetConfig):
    """Compiled serving path: fused implicit-GEMM convs + the quantization-
    domain pass — one ``act_quant`` per block, int8 activations between the
    a/b/c convs AND on every block edge (producer-side quantization: each
    unit emits ``(int8, scale[row])``, so slicing the unit list into
    pipeline stages moves only 8-bit feature maps and cannot change the
    math).  Quantization domains are PER ROW (per image): every scale on
    every edge is an ``(N,)`` vector reduced over H·W·C only, so each
    image's entire forward is independent of its batch neighbours and any
    packing of rows into microbatches is bit-identical (DESIGN.md §9).
    The identity shortcut consumes the quantized block input — the FPGA's
    shortcut reads the same 8-bit inter-layer map (paper SS II-D.4).
    """
    carry = x
    for u in compiled_units(params, cfg):
        carry = u.fn(u.params, carry)
    return carry


def apply(params, x, cfg: ResNetConfig):
    """x: (B, H, W, 3) -> logits (B, num_classes)."""
    if isinstance(params["stem"]["w"], dict):      # compiled constant params
        return _apply_compiled(params, x, cfg)
    h = _conv_apply(params["stem"], x, 7, stride=2)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for i in range(4):
        name, n_blocks, mid, out, hw = cfg.stage(i)
        for b, blk in enumerate(params[name]):
            stride = _block_stride(name, b)
            sc = (_conv_apply(blk["sc"], h, 1, stride, relu=False)
                  if "sc" in blk else h)
            y = _conv_apply(blk["a"], h, 1, stride)
            y = _conv_apply(blk["b"], y, 3)
            h = _conv_apply(blk["c"], y, 1, relu=True, shortcut=sc)
    pooled = jnp.mean(h, axis=(1, 2))
    return apply_linear(params["head"]["w"], pooled)

"""Conv-DAG graph IR + topological compiler (DESIGN.md §12).

The compile path used to be hardwired to the ResNet50 bottleneck chain:
``resnet.compiled_units`` enumerated stem/block/head by hand and
``partition.plan_stages`` assumed that linear block list.  This module
generalizes it: a model is a **graph** of ops over quantization-domain
edges, and everything downstream — unit compilation, stage planning,
the pipeline engine, the replicated frontend — consumes the graph.

*Nodes* are ops (``input``, ``quant``, ``dequant``, ``conv``, ``dwconv``,
``pool``, ``head``); *edges* are activation tensors, and every edge a
pipeline stage boundary may cut carries the ``(int8, scale[row])``
quantization-domain pair — the paper's 8-bit inter-chip link with one
independent scale per image (DESIGN.md §9), so any packing of rows into
microbatches stays bit-identical.  Residual adds are never standalone
nodes: an add is always fused as the consuming conv's ``shortcut``
epilogue argument (the paper's Collector does the add, SS II-D.4), so
the graph stays a DAG of kernel launches, not of scalar ops.

``Graph.units()`` cuts the DAG into pipeline units at **articulation
edges**: after a node whose value is (a) a quantization-domain pair and
(b) the ONLY live value — every earlier value already fully consumed —
the schedule may place a stage boundary, because exactly one (int8,
scale[row]) tensor would cross it.  A segment must contain at least one
conv to close (quant-only prefixes fold into their consumer), and the
trailing segment must be conv-free — it becomes the head unit that rides
the last stage (``block_id`` -1), exactly the old ResNet contract.

``compile_graph`` turns the units into ``PipelineUnit``s — each a pure
function of its own param subtrees, executing its nodes in deterministic
topological order — and ``apply_graph`` runs them end to end, which IS
the single-device compiled forward (the old ``resnet._apply_compiled``,
now one graph builder among several).
"""
from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp

from repro.core.compiled_linear import act_quant, apply_conv, apply_linear
from repro.core.fpga_model import ConvLayerSpec


class GraphError(ValueError):
    """A malformed model graph (shape mismatch, cycle, bad op wiring)."""


OPS = ("input", "quant", "dequant", "conv", "dwconv", "pool", "head")

# value kinds flowing along edges:
#   f32  — float NHWC activations (or the input image)
#   qt   — the (int8 NHWC, f32 scale[row]) quantization-domain pair
#   out  — the head's f32 logits
_F32, _QT, _OUT = "f32", "qt", "out"


@dataclasses.dataclass(frozen=True)
class Node:
    """One op of a model graph.

    ``inputs`` names the producer node(s) (all ops here are unary in
    their main input; the residual add rides ``shortcut``).  ``path`` is
    the param-tree path of the op's weights (conv/dwconv: a dict with
    ``w``/``scale``/``bias``; head: a dict with ``w``).  ``unit`` is an
    optional unit-label hint — the segment containing this node takes the
    first such label as its name.
    """

    name: str
    op: str
    inputs: tuple = ()
    path: tuple = ()
    k: int = 0
    stride: int = 1
    c_in: int = 0
    c_out: int = 0
    relu: bool = True
    quant_out: bool = False
    shortcut: str | None = None
    unit: str | None = None


@dataclasses.dataclass(frozen=True)
class ValueInfo:
    """Static shape/kind of one edge value: (hw, hw, ch) spatial map of
    ``kind`` ('f32' | 'qt' | 'out')."""

    hw: int
    ch: int
    kind: str


@dataclasses.dataclass(frozen=True)
class PipelineUnit:
    """One schedulable unit of the compiled forward.

    ``fn(params, carry) -> carry`` is a pure function of the unit's OWN
    param subtree (``params`` here), so a pipeline stage holds exactly its
    units' constant weights and nothing else — the paper's persistent
    per-chip network.  Every edge between units is the quantization-domain
    pair ``(int8 activations, f32 scale[row])`` — the 8-bit inter-chip
    link, with one independent scale PER IMAGE (per-row domains,
    DESIGN.md §9) so serving may pack rows from different requests into
    one microbatch without any row's bits depending on its neighbours —
    except the f32 image into the first unit and the f32 logits out of
    the head.  ``block_id`` indexes the graph's ``blocks()`` list so
    ``partition.StagePlan``s map 1:1 onto units; the head rides the last
    stage (``block_id`` -1).
    """

    name: str
    block_id: int
    params: dict
    fn: object


@dataclasses.dataclass(frozen=True)
class Graph:
    """A conv-DAG model: nodes + the input image geometry."""

    name: str
    nodes: tuple
    in_hw: int
    in_ch: int
    num_classes: int

    def __post_init__(self):
        seen = set()
        for n in self.nodes:
            if n.op not in OPS:
                raise GraphError(f"{n.name}: unknown op {n.op!r}")
            if n.name in seen:
                raise GraphError(f"duplicate node name {n.name!r}")
            seen.add(n.name)
        for n in self.nodes:
            for ref in n.inputs + ((n.shortcut,) if n.shortcut else ()):
                if ref not in seen:
                    raise GraphError(f"{n.name}: unknown input {ref!r}")

    # -- structure ---------------------------------------------------------

    def topo_order(self) -> tuple:
        """Deterministic Kahn topological order: among ready nodes, the
        earliest-declared runs first — so builders that already append in
        dataflow order compile to exactly that order, and any permutation
        of independent declarations yields the same schedule."""
        index = {n.name: i for i, n in enumerate(self.nodes)}
        indeg = {n.name: 0 for n in self.nodes}
        consumers: dict = {n.name: [] for n in self.nodes}
        for n in self.nodes:
            deps = set(n.inputs) | ({n.shortcut} if n.shortcut else set())
            indeg[n.name] = len(deps)
            for d in deps:
                consumers[d].append(n.name)
        ready = [index[n.name] for n in self.nodes if indeg[n.name] == 0]
        heapq.heapify(ready)
        order = []
        while ready:
            i = heapq.heappop(ready)
            node = self.nodes[i]
            order.append(node)
            for c in consumers[node.name]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    heapq.heappush(ready, index[c])
        if len(order) != len(self.nodes):
            raise GraphError(f"graph {self.name!r} has a cycle")
        return tuple(order)

    def shapes(self) -> dict:
        """name -> ValueInfo for every node's output value, checked: conv
        inputs must be quantization-domain pairs with matching channels,
        shortcuts must be f32 maps of the conv's own output shape."""
        info: dict = {}
        for n in self.topo_order():
            if n.op == "input":
                info[n.name] = ValueInfo(self.in_hw, self.in_ch, _F32)
                continue
            src = info[n.inputs[0]]
            if n.op == "quant":
                if src.kind != _F32:
                    raise GraphError(f"{n.name}: quant of {src.kind} value")
                info[n.name] = ValueInfo(src.hw, src.ch, _QT)
            elif n.op == "dequant":
                if src.kind != _QT:
                    raise GraphError(f"{n.name}: dequant of {src.kind}")
                info[n.name] = ValueInfo(src.hw, src.ch, _F32)
            elif n.op in ("conv", "dwconv"):
                if src.kind != _QT:
                    raise GraphError(
                        f"{n.name}: conv consumes (int8, scale) edges, "
                        f"got {src.kind} from {n.inputs[0]!r}")
                if src.ch != n.c_in:
                    raise GraphError(
                        f"{n.name}: c_in={n.c_in} but input "
                        f"{n.inputs[0]!r} has {src.ch} channels")
                if n.op == "dwconv" and n.c_out != n.c_in:
                    raise GraphError(f"{n.name}: depthwise needs "
                                     f"c_out == c_in, got {n.c_in}->{n.c_out}")
                hw = -(-src.hw // n.stride)
                info[n.name] = ValueInfo(hw, n.c_out,
                                         _QT if n.quant_out else _F32)
                if n.shortcut is not None:
                    if n.op == "dwconv":
                        raise GraphError(f"{n.name}: depthwise epilogue "
                                         "shortcut unsupported by design "
                                         "(no model needs it)")
                    sc = info[n.shortcut]
                    if sc.kind != _F32 or (sc.hw, sc.ch) != (hw, n.c_out):
                        raise GraphError(
                            f"{n.name}: shortcut {n.shortcut!r} is "
                            f"{sc.kind} {sc.hw}x{sc.hw}x{sc.ch}, need f32 "
                            f"{hw}x{hw}x{n.c_out}")
            elif n.op == "pool":
                if src.kind != _F32:
                    raise GraphError(f"{n.name}: pool of {src.kind}")
                info[n.name] = ValueInfo(-(-src.hw // n.stride), src.ch, _F32)
            elif n.op == "head":
                if src.kind != _QT:
                    raise GraphError(f"{n.name}: head consumes a "
                                     f"(int8, scale) edge, got {src.kind}")
                info[n.name] = ValueInfo(1, self.num_classes, _OUT)
        return info

    def units(self) -> list:
        """Cut the DAG at articulation edges -> [(unit_name, [nodes])].

        A cut is legal after node v iff v's value is a quantization-domain
        pair AND it is the only live value (every earlier value has no
        remaining consumer) AND the open segment contains a conv.  The
        trailing segment must be conv-free (the head unit).
        """
        order = self.topo_order()
        info = self.shapes()
        remaining = {n.name: 0 for n in order}
        for n in order:
            deps = set(n.inputs) | ({n.shortcut} if n.shortcut else set())
            for d in deps:
                remaining[d] += 1
        segments, seg, live = [], [], set()
        for n in order:
            seg.append(n)
            for d in set(n.inputs) | ({n.shortcut} if n.shortcut else set()):
                remaining[d] -= 1
                if remaining[d] == 0:
                    live.discard(d)
            if remaining[n.name] > 0:
                live.add(n.name)
            has_conv = any(m.op in ("conv", "dwconv") for m in seg)
            if (live == {n.name} and info[n.name].kind == _QT and has_conv):
                segments.append(seg)
                seg = []
        if seg:
            if any(m.op in ("conv", "dwconv") for m in seg):
                raise GraphError(
                    f"graph {self.name!r}: trailing segment holds conv "
                    f"nodes {[m.name for m in seg]} past the last "
                    "quantization-domain cut — the head unit must be "
                    "conv-free")
            segments.append(seg)
        names, counts = [], {}
        for s in segments[:-1]:
            label = next((m.unit for m in s if m.unit is not None), None)
            label = label if label is not None else f"unit{len(names)}"
            counts[label] = counts.get(label, 0) + 1
            names.append(label if counts[label] == 1
                         else f"{label}.{counts[label]}")
        names.append("head")
        return list(zip(names, segments))

    # -- analytic views (partitioning) ------------------------------------

    def blocks(self) -> list:
        """Per-unit conv specs for the Fig 7 planner: one
        ``list[ConvLayerSpec]`` per non-head unit, in unit order — the
        DAG-general replacement for ``resnet.conv_blocks_for``'s
        hand-built list.  Depthwise layers report ``c_in=1`` so their
        analytic MACs come out to k*k*C*hw*hw."""
        info = self.shapes()
        out = []
        for _, seg in self.units()[:-1]:
            specs = []
            for n in seg:
                if n.op in ("conv", "dwconv"):
                    c_in = 1 if n.op == "dwconv" else n.c_in
                    specs.append(ConvLayerSpec(n.name, c_in, n.c_out, n.k,
                                               info[n.name].hw,
                                               stride=n.stride))
            out.append(specs)
        return out

    def edge_bytes(self) -> list:
        """int8 bytes per image on each unit's outgoing cut edge (the
        8-bit inter-chip link), in unit order — what a ``StagePlan``
        cutting after that unit actually moves.  Replaces
        ``partition.edge_bytes_after_block``'s ResNet-only stem/maxpool
        special case with the graph's real shapes."""
        info = self.shapes()
        out = []
        for _, seg in self.units()[:-1]:
            v = info[seg[-1].name]
            out.append(v.hw * v.hw * v.ch)
        return out

    def in_shape(self) -> tuple:
        """Expected per-image input shape (H, W, C) at the front door."""
        return (self.in_hw, self.in_hw, self.in_ch)


# ---------------------------------------------------------------------------
# Compilation: graph -> pipeline units / single-device forward
# ---------------------------------------------------------------------------

def _row_scale(s):
    """Broadcast a per-row ``(N,)`` scale (or a scalar) over NHWC values."""
    return jnp.asarray(s).reshape((-1,) + (1,) * 3)


def _subtree(params, path):
    sub = params
    for p in path:
        sub = sub[p]
    return sub


def _unit_fn(nodes, sparsity_groups):
    """Compile one unit segment into ``fn(params, carry) -> carry`` (or
    ``(carry, aux)`` when profiled).

    Nodes execute in the segment's (topological) order over a value
    environment; a reference to a name produced in an EARLIER unit
    resolves to the incoming carry — the cut rule guarantees exactly one
    such value exists.  With ``sparsity_groups``, every ReLU-output conv
    emits its zero-count aux under the node's name (obs/sparsity.py
    aggregates); carries are bit-identical either way.
    """
    g = sparsity_groups
    profiled = g is not None

    def fn(p, carry):
        env, aux = {}, {}

        def val(name):
            return env[name] if name in env else carry

        out = carry
        for n in nodes:
            if n.op == "input":
                out = carry
            elif n.op == "quant":
                out = act_quant(val(n.inputs[0]), per_row=True)
            elif n.op == "dequant":
                q, s = val(n.inputs[0])
                out = q.astype(jnp.float32) * _row_scale(s)
            elif n.op in ("conv", "dwconv"):
                q, s = val(n.inputs[0])
                sc = None if n.shortcut is None else val(n.shortcut)
                w = p[n.name]
                zc = g if (profiled and n.relu) else None
                out = apply_conv(w["w"], q, s, gamma=w["scale"],
                                 beta=w["bias"], shortcut=sc, relu=n.relu,
                                 quant_out=n.quant_out, zero_count=zc)
                if zc is not None:
                    aux[n.name] = out[-1]
                    out = out[0] if not n.quant_out else (out[0], out[1])
            elif n.op == "pool":
                out = jax.lax.reduce_window(
                    val(n.inputs[0]), -jnp.inf, jax.lax.max,
                    (1, n.k, n.k, 1), (1, n.stride, n.stride, 1), "SAME")
            elif n.op == "head":
                q, s = val(n.inputs[0])
                pooled = jnp.mean(q.astype(jnp.float32) * _row_scale(s),
                                  axis=(1, 2))
                # per_row: the head's input quantization must not couple
                # rows either, or a request's logits would depend on its
                # microbatch neighbours
                out = apply_linear(p[n.name]["w"], pooled, per_row=True)
            env[n.name] = out
        return (out, aux) if profiled else out

    return fn


def compile_graph(graph: Graph, params,
                  sparsity_groups: int | None = None) -> list:
    """The compiled forward of any conv-DAG as an ordered ``PipelineUnit``
    list — the DAG-general ``resnet.compiled_units``.

    Each unit's ``params`` maps its nodes' names to their param subtrees
    (so a stage device_puts exactly its own constant weights), and
    ``block_id`` is the unit's index into ``graph.blocks()`` (head -1).
    ``sparsity_groups`` opts every ReLU-output conv into activation-
    sparsity profiling: unit fns then return ``(carry, {node: aux})``.
    """
    units = []
    segs = graph.units()
    for j, (uname, seg) in enumerate(segs):
        sub = {n.name: _subtree(params, n.path) for n in seg if n.path}
        bid = -1 if j == len(segs) - 1 else j
        units.append(PipelineUnit(uname, bid, sub,
                                  _unit_fn(seg, sparsity_groups)))
    return units


def apply_graph(graph: Graph, params, x):
    """Single-device compiled forward: run every unit in order.  The
    quantization-domain pass — one producer-side ``act_quant`` per cut
    edge, int8 activations inside and between units, per-row scales
    end to end — is the graph's own structure, so slicing the unit list
    into pipeline stages cannot change the math (DESIGN.md §7, §9)."""
    carry = x
    for u in compile_graph(graph, params):
        carry = u.fn(u.params, carry)
    return carry

"""Minimal functional parameter/module utilities (no flax dependency).

Parameters are plain pytrees of ``Param`` leaves.  A ``Param`` carries the
array (or a ShapeDtypeStruct during shape-only init) plus *logical* axis
names; ``distributed.sharding_rules`` maps logical axes -> mesh axes to build
``PartitionSpec`` trees that always match the parameter tree structure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter leaf: array value + logical axis names (one per dim).

    kind='linear' marks weights eligible for constant-parameter compilation
    (core.compiled_linear.compile_params); everything else is 'generic'.
    """

    value: Any
    axes: tuple = ()
    kind: str = "generic"

    def tree_flatten(self):
        return (self.value,), (self.axes, self.kind)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0], aux[1])

    @property
    def shape(self):
        return self.value.shape

    @property
    def dtype(self):
        return self.value.dtype


def param(key, shape, axes, dtype=jnp.float32, init="normal", scale=None,
          kind="generic"):
    """Create an initialized Param with logical axes.

    init: 'normal' (trunc-normal fan-in), 'zeros', 'ones'.
    """
    assert len(axes) == len(shape), (axes, shape)
    if init == "zeros":
        value = jnp.zeros(shape, dtype)
    elif init == "ones":
        value = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[0] if len(shape) >= 1 else 1
            scale = 1.0 / np.sqrt(max(1, fan_in))
        value = (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)
    return Param(value, tuple(axes), kind)


def linear_param(key, d_in, d_out, axes, dtype=jnp.float32, scale=None):
    """A matmul weight eligible for constant-parameter compilation."""
    return param(key, (d_in, d_out), axes, dtype, "normal", scale, kind="linear")


def conv_kind(k: int, stride: int) -> str:
    """Param kind for a conv weight — the (k, stride) geometry rides the
    kind string so it survives flatten/unflatten, Axes boxing, and
    checkpointing without changing the Param aux structure."""
    return f"conv{k}s{stride}"


def conv_geom_of(kind) -> tuple | None:
    """(k, stride) of a conv kind, or None for non-conv kinds.

    ``dwconv...`` kinds do NOT start with ``conv``, so depthwise weights
    never mis-parse as dense convs here — they have their own
    ``dwconv_geom_of`` and a distinct compiled storage shape."""
    if isinstance(kind, str) and kind.startswith("conv"):
        ks, _, ss = kind[4:].partition("s")
        if ks.isdigit() and ss.isdigit():
            return int(ks), int(ss)
    return None


def dwconv_kind(k: int, stride: int) -> str:
    """Param kind for a depthwise conv weight (groups == channels)."""
    return f"dwconv{k}s{stride}"


def dwconv_geom_of(kind) -> tuple | None:
    """(k, stride) of a depthwise conv kind, or None otherwise."""
    if isinstance(kind, str) and kind.startswith("dwconv"):
        ks, _, ss = kind[6:].partition("s")
        if ks.isdigit() and ss.isdigit():
            return int(ks), int(ss)
    return None


def compilable(kind) -> bool:
    """Kinds eligible for constant-parameter compilation."""
    return (kind == "linear" or conv_geom_of(kind) is not None
            or dwconv_geom_of(kind) is not None)


def conv_param(key, c_in, c_out, k, stride, axes, dtype=jnp.float32,
               scale=None):
    """A conv weight, stored flat (c_in*k*k, c_out) in im2col patch order
    (channel-major), carrying its (k, stride) geometry in the kind."""
    return param(key, (c_in * k * k, c_out), axes, dtype, "normal", scale,
                 kind=conv_kind(k, stride))


def dwconv_param(key, c, k, stride, axes, dtype=jnp.float32, scale=None):
    """A depthwise conv weight, stored (k*k, c) in tap-major row order —
    already the depthwise kernel's consumption layout (one (c,) weight row
    per receptive-field tap), so compilation needs no layout shuffle."""
    return param(key, (k * k, c), axes, dtype, "normal", scale,
                 kind=dwconv_kind(k, stride))


def unbox(tree: PyTree) -> PyTree:
    """Strip Param boxes -> raw array pytree (used inside jitted steps)."""
    return jax.tree.map(lambda p: p.value, tree, is_leaf=lambda x: isinstance(x, Param))


class Axes:
    """Opaque (non-pytree) holder for a logical-axes tuple + kind leaf."""

    __slots__ = ("axes", "kind")

    def __init__(self, axes, kind="generic"):
        self.axes = tuple(axes)
        self.kind = kind

    def __repr__(self):
        return f"Axes{self.axes}[{self.kind}]"

    def __eq__(self, other):
        return (isinstance(other, Axes) and self.axes == other.axes
                and self.kind == other.kind)


def boxed_axes(tree: PyTree) -> PyTree:
    """Parallel pytree with opaque Axes leaves (same structure as unbox())."""
    return jax.tree.map(lambda p: Axes(p.axes, p.kind), tree,
                        is_leaf=lambda x: isinstance(x, Param))


def rebox(values: PyTree, axes: PyTree) -> PyTree:
    return jax.tree.map(lambda v, a: Param(v, a.axes, a.kind), values, axes)


def map_params(fn: Callable, tree: PyTree) -> PyTree:
    return jax.tree.map(lambda p: Param(fn(p.value), p.axes, p.kind), tree,
                        is_leaf=lambda x: isinstance(x, Param))


def count_params(tree: PyTree) -> int:
    vals = jax.tree.leaves(unbox(tree))
    return int(sum(np.prod(v.shape) for v in vals))


def param_bytes(tree: PyTree) -> int:
    vals = jax.tree.leaves(unbox(tree))
    return int(sum(np.prod(v.shape) * v.dtype.itemsize for v in vals))


def split_keys(key, n):
    return list(jax.random.split(key, n))


def vmap_init(init_fn: Callable, key, n: int, *args, **kwargs):
    """Initialize ``n`` stacked copies of a layer (for lax.scan over layers).

    The stacked leading axis gets logical axis name 'layers'.
    """
    keys = jax.random.split(key, n)
    stacked = jax.vmap(lambda k: init_fn(k, *args, **kwargs))(keys)
    return jax.tree.map(
        lambda p: Param(p.value, ("layers",) + p.axes, p.kind),
        stacked, is_leaf=lambda x: isinstance(x, Param))

"""Throughput-balanced multi-chip partitioning — paper SS III / Fig 7.

Given a network's layer list and a target throughput, size every layer's
kernel with the calibrated FPGA model (core.fpga_model.plan_layer), then
greedily pack layers into chips in dataflow order subject to:

  * a Residual Block must be fully contained in one chip (keeps the
    shortcut on-chip, paper SS II-C);
  * chip ALM utilization <= util_target;
  * inter-chip links carry 8-bit feature maps at the pipeline rate and
    must stay under max_link_gbps (75 Gbps in Fig 7).

The same planner drives the TPU mapping: `plan_layer`'s fold/instances
become per-stage replication and microbatch counts for the pipeline-
parallel serving engine (serving/engine.py), i.e. the paper's kernel
folding / multi-instance scheme re-expressed as TDM over a systolic core.
"""
from __future__ import annotations

import dataclasses

from repro.core import fpga_model
from repro.core.fpga_model import FPGASpec, GX280, GX550, ConvLayerSpec


class PartitionError(ValueError):
    """A layer/block cannot be placed within the chip's usable fabric.

    Raised instead of silently emitting chips above ``util_target`` (the
    old packer gave every oversized kernel instance its own >100%-utilized
    chip and reported success)."""


@dataclasses.dataclass
class Chip:
    index: int
    layers: list
    alms_used: float = 0.0

    def utilization(self, spec: FPGASpec) -> float:
        return self.alms_used / spec.alms


@dataclasses.dataclass
class PartitionResult:
    chips: list
    target_im_s: float
    achieved_im_s: float       # min(target, slowest folded block)
    link_gbps: list            # between consecutive chips
    spec: FPGASpec
    bottleneck: str = ""

    @property
    def n_chips(self) -> int:
        return len(self.chips)

    @property
    def im_s_per_chip(self) -> float:
        return self.achieved_im_s / max(self.n_chips, 1)

    @property
    def max_link_gbps(self) -> float:
        return max(self.link_gbps, default=0.0)

    def summary(self) -> dict:
        return dict(
            n_chips=self.n_chips,
            target_im_s=self.target_im_s,
            achieved_im_s=self.achieved_im_s,
            im_s_per_chip=self.im_s_per_chip,
            bottleneck=self.bottleneck,
            max_link_gbps=self.max_link_gbps,
            chip_utilization=[round(c.utilization(self.spec), 3)
                              for c in self.chips],
        )

    def stage_plans(self, blocks: list, n_stages: int | None = None,
                    edge_bytes: list | None = None) -> list:
        """Executable ``StagePlan``s for this partition (see stage_plans)."""
        return stage_plans(self, blocks, n_stages, edge_bytes)


def partition(blocks: list[list[ConvLayerSpec]], target_im_s: float,
              spec: FPGASpec = GX280, util_target: float = 0.76,
              batch: int = 2) -> PartitionResult:
    """Pack residual blocks into chips in dataflow order.

    Blocks are kept on one chip where they fit (the paper's requirement);
    blocks larger than a whole chip — conv5_1 with its 2048x2048 projection
    shortcut cannot fit a GX280 at any useful fold — are split at layer
    granularity with the shortcut crossing chips (documented deviation:
    DESIGN.md notes the paper's Fig 7 must do the same or de-rate).
    Pipeline throughput = min over kernels of their folded capability.
    """
    cap = spec.usable_alms(util_target)
    achieved, bottleneck = float("inf"), ""
    chips: list[Chip] = [Chip(0, [])]
    for blk in blocks:
        plans = [fpga_model.plan_layer(l, target_im_s, chip=spec,
                                       util_target=util_target) for l in blk]
        for p in plans:
            if p["im_s_capable"] < achieved:
                achieved, bottleneck = p["im_s_capable"], p["layer"]
        blk_alms = sum(p["alms"] for p in plans)
        if blk_alms <= cap:  # atomic placement
            if chips[-1].alms_used + blk_alms > cap and chips[-1].layers:
                chips.append(Chip(len(chips), []))
            chips[-1].layers.extend(
                {**p, "spec": l} for p, l in zip(plans, blk))
            chips[-1].alms_used += blk_alms
        else:                # oversized block: layer/instance-granular split
            for p, l in zip(plans, blk):
                per_inst = p["alms"] / max(p["instances"], 1)
                if per_inst > cap:
                    # even one kernel instance (at the cost model's maximum
                    # useful fold) overflows the usable fabric: error out
                    # rather than emitting a >util_target chip
                    raise PartitionError(
                        f"layer {l.name}: one instance needs "
                        f"{per_inst / 1e3:.0f}k ALMs at fold {p['fold']} "
                        f"but only {cap / 1e3:.0f}k are usable on "
                        f"{spec.name} at util_target={util_target}")
                for _ in range(max(p["instances"], 1)):
                    if (chips[-1].alms_used + per_inst > cap
                            and chips[-1].layers):
                        chips.append(Chip(len(chips), []))
                    chips[-1].layers.append(
                        {**p, "alms": per_inst, "spec": l,
                         "split_block": True})
                    chips[-1].alms_used += per_inst
    achieved = min(achieved, target_im_s)
    # inter-chip links: 8-bit activations at the pipeline rate; double-
    # buffered boundaries (paper SS II-D.1) don't change steady-state rate.
    link_gbps = []
    for chip in chips[:-1]:
        out_layer = chip.layers[-1]["spec"]
        gbps = out_layer.out_bytes * 8 * achieved / 1e9
        link_gbps.append(gbps)
    return PartitionResult(chips, target_im_s, achieved, link_gbps, spec,
                           bottleneck)


def solve_max_throughput(blocks, spec: FPGASpec = GX280,
                         util_target: float = 0.76,
                         max_link_gbps: float = 75.0,
                         lo: float = 1_000.0, hi: float = 200_000.0) -> PartitionResult:
    """Find the highest target im/s whose partition respects the link cap
    and yields the best im/s/chip (bisection over the target)."""
    best = partition(blocks, lo, spec, util_target)
    for _ in range(24):
        mid = 0.5 * (lo + hi)
        r = partition(blocks, mid, spec, util_target)
        if r.max_link_gbps <= max_link_gbps:
            if r.im_s_per_chip >= best.im_s_per_chip:
                best = r
            lo = mid
        else:
            hi = mid
    return best


def fig7_projection(spec: FPGASpec = GX280) -> dict:
    """Reproduce the paper's Fig 7 projection and compare to its claims."""
    from repro.models.resnet import resnet50_conv_blocks
    blocks = resnet50_conv_blocks()
    claimed = fpga_model.FIG7
    ours = partition(blocks, claimed["im_s_total"], spec)
    best = solve_max_throughput(blocks, spec)
    v100 = claimed["v100_sparse_bound"]
    return dict(
        paper_claim=claimed,
        at_paper_target=ours.summary(),
        model_best=best.summary(),
        gx550_scaling=dict(
            im_s_per_chip=best.im_s_per_chip * GX550.alms / spec.alms,
            speedup_vs_v100_bound=(best.im_s_per_chip * GX550.alms
                                   / spec.alms) / v100,
        ),
    )


# ---------------------------------------------------------------------------
# Executable stage plans (the Fig 7 partition as a runnable pipeline)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One pipeline stage of the *executable* multi-device serving path.

    ``block_ids`` index the network's block list (``resnet.conv_blocks_for``
    order: 0 = the stem, 1.. = residual blocks); the serving engine maps
    them 1:1 onto its pipeline units, with the classifier head riding the
    last stage.  ``link_bytes`` is the analytic int8 activation payload
    this stage sends downstream per image — the paper's 8-bit inter-chip
    link, cross-checked against the bytes the executed pipeline actually
    moves (tests/test_pipeline.py).
    """

    index: int
    block_ids: tuple
    layer_names: tuple
    link_bytes: int            # int8 bytes/image on the outgoing edge (0: last)
    macs: int = 0
    alms: float = 0.0

    def link_gbps(self, im_s: float) -> float:
        return self.link_bytes * 8 * im_s / 1e9


def edge_bytes_after_block(blocks: list, j: int) -> int:
    """int8 activation bytes per image leaving block ``j`` — the ResNet
    convention: block 0 is a stem whose executable unit max-pools 2x2
    after its conv, so the stem edge carries a quarter of conv1's map.

    DAG-general models don't follow that convention; the planner entry
    points below accept an explicit per-block ``edge_bytes`` list
    (``models.graph.Graph.edge_bytes`` computes it from the graph's real
    cut-edge shapes) and fall back to this legacy accounting when given
    none — for ResNet the two agree exactly (tested).
    """
    spec = blocks[j][-1]
    if j == 0:
        hw = -(-spec.hw // 2)          # SAME stride-2 maxpool
        return hw * hw * spec.c_out
    return spec.out_bytes


def split_stages(costs: list, n_stages: int) -> list:
    """Balanced contiguous split of ``costs`` into ``n_stages`` non-empty
    groups (greedy threshold; never emits fewer groups than asked while
    items remain)."""
    n_stages = max(1, min(n_stages, len(costs)))
    total = float(sum(costs))
    target = total / n_stages
    groups, cur, acc = [], [], 0.0
    for i, c in enumerate(costs):
        # adding item i to cur must leave enough items for the remaining
        # groups; close cur first when it would not
        if cur and len(costs) - i < n_stages - len(groups):
            groups.append(tuple(cur))
            cur, acc = [], 0.0
        cur.append(i)
        acc += float(c)
        if acc >= target and len(groups) < n_stages - 1:
            groups.append(tuple(cur))
            cur, acc = [], 0.0
    if cur:
        groups.append(tuple(cur))
    return groups


def _plans_from_groups(blocks: list, groups: list,
                       alms_per_block: list | None = None,
                       edge_bytes: list | None = None) -> list:
    plans = []
    for s, ids in enumerate(groups):
        names = tuple(l.name for j in ids for l in blocks[j])
        link = 0 if s == len(groups) - 1 else (
            edge_bytes[ids[-1]] if edge_bytes is not None
            else edge_bytes_after_block(blocks, ids[-1]))
        macs = int(sum(l.macs for j in ids for l in blocks[j]))
        alms = (sum(alms_per_block[j] for j in ids)
                if alms_per_block is not None else 0.0)
        plans.append(StagePlan(s, tuple(ids), names, link, macs, alms))
    return plans


def plan_stages(blocks: list, n_stages: int,
                edge_bytes: list | None = None) -> list:
    """MAC-balanced contiguous ``StagePlan``s along block boundaries —
    the explicit-stage-map path (no FPGA cost model involved)."""
    groups = split_stages([sum(l.macs for l in blk) for blk in blocks],
                          n_stages)
    return _plans_from_groups(blocks, groups, edge_bytes=edge_bytes)


def explicit_stage_plans(blocks: list, groups: list,
                         edge_bytes: list | None = None) -> list:
    """``StagePlan``s from an explicit stage map (tuple of block-id tuples
    — must be a contiguous in-order partition of the block list)."""
    flat = [j for g in groups for j in g]
    assert flat == list(range(len(blocks))), (
        "stage map must cover blocks 0..%d contiguously" % (len(blocks) - 1),
        groups)
    return _plans_from_groups(blocks, [tuple(g) for g in groups],
                              edge_bytes=edge_bytes)


def stage_plans(result: PartitionResult, blocks: list,
                n_stages: int | None = None,
                edge_bytes: list | None = None) -> list:
    """Executable stages from a Fig 7 chip packing.

    Chip boundaries are re-aligned to block boundaries (a block whose
    layers were instance-split across chips folds into the stage owning
    its first layer — the executable granularity is the residual block,
    which keeps every shortcut on-stage).  With ``n_stages`` the chip
    grouping is re-balanced by per-block ALMs into that many contiguous
    stages (serving fewer devices than Fig 7 chips).
    """
    chip_of_layer, layer_order = {}, []
    alms_of_layer = {}
    for chip in result.chips:
        for p in chip.layers:
            if p["layer"] not in chip_of_layer:
                chip_of_layer[p["layer"]] = chip.index
                layer_order.append(p["layer"])
            alms_of_layer[p["layer"]] = (alms_of_layer.get(p["layer"], 0.0)
                                         + p["alms"])
    if not all(l.name in chip_of_layer for blk in blocks for l in blk):
        # the result was solved over a structurally-equal block list with
        # different layer names (e.g. a Fig 7 packing of the legacy
        # ResNet-convention specs applied to graph-derived blocks):
        # re-key it positionally — same chain, so the i-th layer of the
        # solve is the i-th layer here
        flat = [l.name for blk in blocks for l in blk]
        if len(flat) != len(layer_order):
            raise ValueError(
                f"partition result covers {len(layer_order)} layers but "
                f"the block list holds {len(flat)}; layer names don't "
                "match and positional alignment is impossible")
        chip_of_layer = {new: chip_of_layer[old]
                         for new, old in zip(flat, layer_order)}
        alms_of_layer = {new: alms_of_layer[old]
                         for new, old in zip(flat, layer_order)}
    block_chip = [chip_of_layer[blk[0].name] for blk in blocks]
    alms_per_block = [sum(alms_of_layer.get(l.name, 0.0) for l in blk)
                      for blk in blocks]
    if n_stages is not None:
        groups = split_stages(alms_per_block, n_stages)
    else:
        groups, cur = [], [0]
        for j in range(1, len(blocks)):
            if block_chip[j] != block_chip[j - 1]:
                groups.append(tuple(cur))
                cur = []
            cur.append(j)
        groups.append(tuple(cur))
    return _plans_from_groups(blocks, groups, alms_per_block, edge_bytes)


# ---------------------------------------------------------------------------
# LM pipeline partitioning (the paper's multi-chip pipeline, for the zoo)
# ---------------------------------------------------------------------------

def partition_lm(cfg, n_stages: int, batch: int = 1, seq: int = 1,
                 serve_mode: str = "sparse_cfmm",
                 link_gbps_budget: float = 75.0) -> dict:
    """Throughput-balanced pipeline stages for an LM (persistent weights).

    The paper's Fig 7 discipline applied to transformers: split the layer
    stack into ``n_stages`` contiguous stages with near-equal per-token
    FLOPs, keep residual blocks atomic, and check the inter-stage link
    bandwidth (activations (B, 1, d_model) per decode step, or (B, S, d)
    for prefill) against the budget.  Weights stay resident per stage —
    the TPU analogue of compiling parameters into each chip.
    """
    from repro.roofline.analytic import BYTES_PER_PARAM

    sigs = cfg.layer_sigs()
    # per-layer forward flops per token (matmul-only; attention excluded as
    # cache-dependent — balancing by linear work matches the paper's
    # MAC-based balance)
    def layer_flops(sig):
        d = cfg.d_model
        f = 0.0
        if sig["kind"] == "attn":
            if cfg.mla:
                m = cfg.mla
                f += 2 * d * cfg.n_heads * (m.qk_nope + m.qk_rope)
                f += 2 * d * (m.kv_lora + m.qk_rope)
                f += 2 * m.kv_lora * cfg.n_heads * (m.qk_nope + m.v_dim)
                f += 2 * cfg.n_heads * m.v_dim * d
            else:
                f += 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                f += 2 * cfg.n_heads * cfg.head_dim * d
        elif sig["kind"] in ("mamba", "rwkv"):
            di = cfg.ssm.d_inner if cfg.ssm.kind == "mamba" else d
            f += 2 * d * 4 * di
        if sig["moe"]:
            m = cfg.moe
            f += 2 * d * m.d_ff_expert * 3 * (m.top_k + m.n_shared)
        elif sig["kind"] != "rwkv":
            f += 2 * d * cfg.d_ff * 3
        else:
            f += 2 * d * cfg.d_ff * 2
        return f

    flops = [layer_flops(s) for s in sigs]
    stages = split_stages(flops, n_stages)
    bpp = BYTES_PER_PARAM.get(serve_mode, 2.0)
    stage_flops = [sum(flops[i] for i in st) for st in stages]
    # per-stage resident weight bytes (flops/token = 2*params for linears)
    stage_weight_gb = [f / 2.0 * bpp / 1e9 for f in stage_flops]
    # inter-stage activation traffic per step
    act_bytes = batch * max(seq, 1) * cfg.d_model * 2
    return dict(
        n_stages=len(stages),
        layers_per_stage=[len(s) for s in stages],
        stage_flops_per_token=stage_flops,
        balance=min(stage_flops) / max(stage_flops),
        boundary_bytes_per_step=act_bytes,
        link_gbps_at_10k_steps_s=act_bytes * 8 * 10_000 / 1e9,
        link_budget_ok=act_bytes * 8 * 10_000 / 1e9 <= link_gbps_budget,
        stage_weight_gb=stage_weight_gb,
        serve_mode=serve_mode,
    )

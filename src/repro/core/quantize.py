"""INT7 per-output-channel symmetric quantization (paper SS II-A).

The paper starts from a model quantized with a modified Ternary Residual
Networks scheme: one scaling factor per output channel and six ternary
residual terms, which is range-equivalent to INT7 (|q| <= 63 = 2^6 - 1),
reported at 0.22% top-1 loss vs FP32.  We implement the equivalent direct
INT7 quantizer (values live in int8 storage), the ternary-residual
decomposition check, activation INT8 quantization (activations are
"saturated and rounded to 8 bits" in the Collector, SS II-D.4), and a
straight-through fake-quant for QAT so models trained here can be compiled.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

INT7_MAX = 63          # 2**6 - 1: six ternary residual terms
INT8_ACT_MAX = 127     # activations saturate/round to 8 bits


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Quantized tensor: int values + float scale broadcastable over values.

    ``values`` are int8 storage holding INT7 (weights) or INT8 (activations)
    codes; ``scale`` has one entry per output channel for weights (paper:
    "each output channel has one independent scaling factor").
    """

    values: jax.Array   # int8
    scale: jax.Array    # f32, broadcastable against values
    axis: int = -1      # channel axis the scale runs over

    def tree_flatten(self):
        return (self.values, self.scale), self.axis

    @classmethod
    def tree_unflatten(cls, axis, children):
        return cls(children[0], children[1], axis)

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self, dtype=jnp.float32):
        return self.values.astype(dtype) * self.scale.astype(dtype)


def _channel_scale(w: jax.Array, axis: int, qmax: int) -> jax.Array:
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
    amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    return jnp.maximum(amax, 1e-12) / qmax


def quantize_int7(w: jax.Array, axis: int = -1) -> QTensor:
    """Symmetric per-output-channel INT7 weight quantization."""
    scale = _channel_scale(w, axis, INT7_MAX)
    q = jnp.clip(jnp.round(w / scale), -INT7_MAX, INT7_MAX).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32), axis)


def quantize_act_int8(x: jax.Array, scale: Optional[jax.Array] = None,
                      per_row: bool = False) -> QTensor:
    """INT8 activation quantization (dynamic if no scale given).

    ``per_row=False`` (default): one tensor-wide scale — the historical
    per-microbatch quantization domain.  ``per_row=True``: one scale per
    leading-axis row (per image for NHWC activations), reduced over every
    other axis with keepdims so ``scale`` broadcasts against ``values`` —
    the quantization domain that lets serving pack rows from *different*
    requests into one microbatch without any row's codes depending on its
    batch neighbours (DESIGN.md §9).
    """
    if scale is None:
        axes = tuple(range(1, x.ndim)) if per_row else None
        amax = jnp.max(jnp.abs(x), axis=axes, keepdims=per_row)
        scale = jnp.maximum(amax, 1e-12) / INT8_ACT_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_ACT_MAX, INT8_ACT_MAX).astype(jnp.int8)
    return QTensor(q, jnp.asarray(scale, jnp.float32),
                   0 if per_row else -1)


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant_int7(w: jax.Array, axis: int = -1) -> jax.Array:
    """QAT fake-quant: INT7 forward numerics, straight-through gradient."""
    scale = _channel_scale(w, axis, INT7_MAX)
    q = jnp.clip(_ste_round(w / scale), -INT7_MAX, INT7_MAX)
    return q * scale


def ternary_residual_decompose(q: jax.Array, terms: int = 6):
    """Decompose INT7 codes into ``terms`` ternary power-of-two residuals.

    Returns t with shape q.shape + (terms,) and t_i in {-1, 0, +1} such that
    sum_i t_i * 2^i == q exactly.  This is the TRN form the paper's source
    model used ("6 residual terms (equivalent to INT7)").
    """
    sign = jnp.sign(q).astype(jnp.int32)
    mag = jnp.abs(q).astype(jnp.int32)
    bits = [(mag >> i) & 1 for i in range(terms)]
    return jnp.stack([b * sign for b in bits], axis=-1).astype(jnp.int8)


def ternary_residual_reconstruct(t: jax.Array) -> jax.Array:
    terms = t.shape[-1]
    weights = jnp.asarray([1 << i for i in range(terms)], jnp.int32)
    return jnp.sum(t.astype(jnp.int32) * weights, axis=-1)


def quantization_error(w: jax.Array, axis: int = -1) -> jax.Array:
    """Relative L2 error of INT7 round-trip (paper: 0.22% accuracy loss)."""
    qt = quantize_int7(w, axis)
    err = jnp.linalg.norm(w - qt.dequantize()) / jnp.maximum(jnp.linalg.norm(w), 1e-12)
    return err

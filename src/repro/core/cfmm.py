"""Common Factor Mass Multiplication (CFMM) — paper SS II-E.1.

The paper's counting argument, reproduced exactly here:

* an INT7 weight magnitude lies in [0, 63];
* the **sign** is moved into the adder tree, equivalence-classing +/-w
  (128 -> 64 unique values);
* **even** products are a (free) left shift of an **odd** product, so only
  the 32 odd magnitudes {1, 3, ..., 63} need computing; x0 and x1 are free.

So one input activation (the *common factor*) needs at most 32 unique
products to serve every weight that multiplies it.  On FPGA these are 30-ish
bit-serial adders; on TPU the same decomposition becomes (a) a 32-entry odd
LUT decode of packed weights into int8 tiles in VMEM (kernels/cfmm_matmul)
and (b) an exact product-table + gather reference kept here as the oracle.

Everything in this module is exact integer math — tests assert bit-equality
against dense int matmuls.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import INT7_MAX

# The 32 unique odd magnitudes of INT7 (paper: "a INT7 CFMM block only has
# 32 unique products").
ODD_VALUES = np.arange(1, INT7_MAX + 1, 2)          # [1, 3, ..., 63]
N_UNIQUE_PRODUCTS = len(ODD_VALUES)                  # == 32

# LUTs over |q| in [0, 63]: |q| = odd(mag_idx) << shift, with mag_idx in
# [0, 32) and shift in [0, 5].  Entry 0 is a don't-care (zero weight).
_MAG_IDX_LUT = np.zeros(INT7_MAX + 1, np.int8)
_SHIFT_LUT = np.zeros(INT7_MAX + 1, np.int8)
for _m in range(1, INT7_MAX + 1):
    _v, _s = _m, 0
    while _v % 2 == 0:
        _v //= 2
        _s += 1
    _MAG_IDX_LUT[_m] = (_v - 1) // 2
    _SHIFT_LUT[_m] = _s
MAX_SHIFT = int(_SHIFT_LUT.max())                    # == 5


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CFMMWeights:
    """Packed constant-parameter form of an INT7 weight tensor.

    sign    in {-1, 0, +1}  (0 encodes a pruned/zero weight)
    mag_idx in [0, 32)      index into ODD_VALUES
    shift   in [0, 5]       left shift applied to the odd product
    scale   per-output-channel dequant scale (f32)

    reconstruct(): sign * (ODD_VALUES[mag_idx] << shift) == original int7.
    """

    sign: jax.Array      # int8
    mag_idx: jax.Array   # int8
    shift: jax.Array     # int8
    scale: jax.Array     # f32 (broadcastable over the weight)

    def tree_flatten(self):
        return (self.sign, self.mag_idx, self.shift, self.scale), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    @property
    def shape(self):
        return self.sign.shape


def decompose(q: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """INT7 codes -> (sign, mag_idx, shift).  Exact for |q| <= 63."""
    sign = jnp.sign(q).astype(jnp.int8)
    mag = jnp.abs(q).astype(jnp.int32)
    mag_idx = jnp.asarray(_MAG_IDX_LUT)[mag]
    shift = jnp.asarray(_SHIFT_LUT)[mag]
    return sign, mag_idx, shift


def reconstruct(sign: jax.Array, mag_idx: jax.Array, shift: jax.Array) -> jax.Array:
    odd = jnp.asarray(ODD_VALUES, jnp.int32)[mag_idx.astype(jnp.int32)]
    return sign.astype(jnp.int32) * (odd << shift.astype(jnp.int32))


def pack(qt_values: jax.Array, scale: jax.Array) -> CFMMWeights:
    sign, mag_idx, shift = decompose(qt_values)
    return CFMMWeights(sign, mag_idx, shift, scale)


def unpack_int8(w: CFMMWeights) -> jax.Array:
    """LUT-decode packed weights back to dense int8 codes (VMEM-side op in
    the Pallas kernel; here as the lowering used on non-TPU backends)."""
    return reconstruct(w.sign, w.mag_idx, w.shift).astype(jnp.int8)


def product_table(x_q: jax.Array) -> jax.Array:
    """All unique odd products of each input value: the CFMM block output.

    x_q: int8 activations (...,).  Returns int32 (..., 32) where
    table[..., k] = x * ODD_VALUES[k].  One input value is the Common
    Factor for all 32 products (paper Fig 3).
    """
    odd = jnp.asarray(ODD_VALUES, jnp.int32)
    return x_q.astype(jnp.int32)[..., None] * odd


def cfmm_matmul_exact(x_q: jax.Array, w: CFMMWeights) -> jax.Array:
    """Product-table CFMM matmul — the faithful FPGA dataflow, exact int32.

    x_q: (M, K) int8; w: packed (K, N).  For every input x[m, k] build the
    32-product table, gather the product selected by mag_idx[k, n], apply
    the free shift, and push the sign into the adder tree (signed add).
    Returns (M, N) int32 == x_q @ reconstruct(w).

    O(M*K*N) gather memory — this is the *oracle*; production paths use
    kernels/cfmm_matmul (LUT decode + MXU) or block-sparse variants.
    """
    table = product_table(x_q)                              # (M, K, 32)
    gathered = jnp.take_along_axis(
        table[:, :, None, :],                               # (M, K, 1, 32)
        w.mag_idx.astype(jnp.int32)[None, :, :, None],      # (1, K, N, 1)
        axis=-1,
    )[..., 0]                                               # (M, K, N)
    shifted = gathered << w.shift.astype(jnp.int32)[None]
    signed = shifted * w.sign.astype(jnp.int32)[None]
    return jnp.sum(signed, axis=1)                          # adder tree over K


def cfmm_matmul_int8(x_q: jax.Array, w) -> jax.Array:
    """Decode-then-MXU CFMM matmul: LUT decode to int8 + int8xint8->int32 dot.

    Mathematically identical to cfmm_matmul_exact; this is the TPU-native
    dataflow (decode happens in VMEM inside the Pallas kernel).  ``w`` may
    be packed CFMMWeights or raw int8 codes (decode is then the identity).
    """
    w_int8 = unpack_int8(w) if isinstance(w, CFMMWeights) else w
    return jax.lax.dot_general(
        x_q, w_int8,
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def bitserial_matmul(x_q: jax.Array, q_codes: jax.Array) -> jax.Array:
    """Bit-plane ("bit-serial") matmul ablation: y = sum_b 2^b * (x @ B_b).

    B_b are the ternary bit-planes of the INT7 codes (quantize.ternary_
    residual_decompose).  The closest TPU analogue of the paper's bit-serial
    adder trees; kept for ablation/benchmarks.  Exact int32.
    """
    sign = jnp.sign(q_codes).astype(jnp.int32)
    mag = jnp.abs(q_codes).astype(jnp.int32)
    acc = jnp.zeros(x_q.shape[:-1] + (q_codes.shape[-1],), jnp.int32)
    for b in range(6):
        plane = (((mag >> b) & 1) * sign).astype(jnp.int8)
        partial = jax.lax.dot_general(
            x_q, plane,
            dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = acc + (partial << b)
    return acc


def unique_product_count(q_codes: jax.Array) -> int:
    """Number of unique odd product magnitudes actually used by a weight
    tensor (paper claim: <= 32 for INT7)."""
    _, mag_idx, _ = decompose(q_codes)
    nz = np.asarray(jnp.abs(q_codes) > 0)
    return int(np.unique(np.asarray(mag_idx)[nz]).size) if nz.any() else 0


def cfmm_flops_saved(q_codes: jax.Array, n_common_uses: int) -> dict:
    """Paper SS II-E.1 accounting: multiplies amortized by the CFMM block.

    A naive implementation multiplies once per (input, nonzero weight) pair;
    CFMM computes <=32 products per input (one add each) and reuses them
    ``n_common_uses`` times (e.g. 2304 for a 3x3x256 filter set, Fig 3).
    """
    nnz = int(np.asarray(jnp.sum(jnp.abs(q_codes) > 0)))
    total = int(np.prod(q_codes.shape))
    return {
        "weights_total": total,
        "weights_nonzero": nnz,
        "sparsity": 1.0 - nnz / max(total, 1),
        "naive_multiplies_per_cf": n_common_uses,
        "cfmm_adds_per_cf": N_UNIQUE_PRODUCTS - 2,  # x1 free, incremental adds
        "amortization": n_common_uses / max(N_UNIQUE_PRODUCTS - 2, 1),
    }

"""CompiledLinear — the paper's technique as a first-class module.

Every parameterized linear map in every architecture (QKV/out projections,
FFN/SwiGLU, MoE experts, MLA projections, Mamba/RWKV projections, conv via
im2col, the LM head) routes through ``apply_linear``.  The weight leaf is,
per serving compilation mode:

  dense        raw bf16/f32 array                      (training / baseline)
  int8         {'values': int8, 'scale'}                W-INT7 A-INT8 QDQ,
               direct int8 MXU matmul (2x bf16 peak)
  cfmm         {'codes': int8, 'scale'}                 same storage; compute
               routed through the CFMM product-table / LUT-decode Pallas
               kernel (kernels/cfmm_matmul) — the paper's dataflow
  sparse_cfmm  {'bitmap': uint8, 'values': int8, 'scale'}
               bitmap-packed constant sparsity: (1-s)*8 + 1 bits/param
               (~2.6 bits at s=0.8 vs 16 for bf16) — the paper's
               zero-overhead sparsity converted to a memory-bandwidth win.
               K pads up to a multiple of 8 with masked all-zero rows
  bitserial    {'codes': int8, 'scale'}, bit-plane matmul — FPGA bit-serial
               ablation (sum_b 2^b * (x @ ternary plane_b))

EVERY conv leaf — packed or dense — is stored in the conv kernels'
spatial-major tap layout (row = tap*c_in + c, kernels/conv_sparse.py) at
compile time: serving streams the stored bytes straight into VMEM and
``ops.conv2d`` performs zero call-time layout shuffles; the single
permute (kernels.ref.to_spatial_major) runs here, once.

``compile_params`` converts a trained parameter tree into its constant-
parameter ("Compiled NN") serving form.  It is jax-traceable, so the
multi-pod dry-run builds packed serving params with jax.eval_shape — no
real weights are ever allocated.

Deviation from the paper (documented in DESIGN.md): pruning for
sparse_cfmm is per-output-channel balanced (top-k per column) rather than
globally unstructured, so the packed value buffer is rectangular with a
static shape.  Overall sparsity is identical; the FPGA needs no such
balance but a static-shape accelerator buffer does.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.core import cfmm
from repro.core.quantize import INT8_ACT_MAX, quantize_int7
from repro.kernels import ref as kref
from repro.kernels.bitmap import expand_bitmap_tile

SERVE_MODES = ("dense", "int8", "cfmm", "sparse_cfmm", "bitserial")


def act_quant(x: jax.Array, *, per_row: bool = False):
    """Dynamic INT8 activation quantization (the Collector saturates/
    rounds activations to 8 bits, paper SS II-D.4).

    ``per_row=False``: one tensor-wide scalar scale (the per-microbatch
    quantization domain).  ``per_row=True``: one scale per leading-axis
    row — scale shape ``(N,)`` for ``(N, ...)`` input — the per-image
    domain the compiled ResNet path serves under, so a row's int8 codes
    never depend on its batch neighbours and microbatches may pack rows
    from different requests (DESIGN.md §9).
    """
    axes = tuple(range(1, x.ndim)) if per_row else None
    amax = jnp.max(jnp.abs(x), axis=axes)
    scale = (jnp.maximum(amax, 1e-12) / INT8_ACT_MAX).astype(jnp.float32)
    s_b = scale.reshape((-1,) + (1,) * (x.ndim - 1)) if per_row else scale
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s_b),
                 -INT8_ACT_MAX, INT8_ACT_MAX).astype(jnp.int8)
    return q, scale


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ConvGeom:
    """Static (k, stride, c_in) geometry riding a compiled conv weight.

    A childless pytree node: it passes through nn.unbox / tree.map /
    eval_shape untouched, so compiled conv leaves stay self-describing —
    consumers never re-plumb filter size or stride alongside the weight.

    ``dw=True`` marks a depthwise leaf (groups == channels): storage is
    tap-major ``(k*k, C)`` and ``apply_conv`` routes it to the depthwise
    tap-MAC kernel (kernels/conv_depthwise.py) instead of implicit-GEMM;
    ``c_in`` is 1 (per-output-channel input fan-in), which also makes the
    analytic ``ConvLayerSpec`` MAC/param counts come out right.
    """

    k: int
    stride: int
    c_in: int
    dw: bool = False

    def tree_flatten(self):
        return (), (self.k, self.stride, self.c_in, self.dw)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*aux)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KDim:
    """Static unpadded-K marker riding an off-%8 *linear* bitmap leaf.

    The pad_rows8 rule stores such leaves with ceil(K/8)*8 rows; this
    childless pytree node (same pattern as ConvGeom) records the original
    K so ``packed_codes``/``dense_of`` keep their shape contract for
    algebraic consumers.  Conv leaves need no marker — their ``geom``
    already determines K = k*k*c_in.
    """

    k: int

    def tree_flatten(self):
        return (), (self.k,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*aux)


# ---------------------------------------------------------------------------
# Bitmap packing (traceable; shapes static given keep_k)
# ---------------------------------------------------------------------------

def balanced_prune_codes(w: jax.Array, keep_k: int) -> jax.Array:
    """Keep the top-``keep_k`` |w| entries per column; quantize to INT7."""
    ranks = jnp.argsort(jnp.argsort(-jnp.abs(w), axis=0, stable=True),
                        axis=0, stable=True)
    pruned = jnp.where(ranks < keep_k, w, 0.0)
    return quantize_int7(pruned, axis=-1)


def bitmap_pack(codes: jax.Array, keep_k: int):
    """int8 codes (K, N) with <= keep_k nonzeros/col -> (bitmap, values).

    bitmap: (K/8, N) uint8, little-endian bit j of row r = mask[8r+j].
    values: (keep_k, N) int8, nonzeros in ascending row order.
    """
    K, N = codes.shape
    assert K % 8 == 0, f"K={K} must be divisible by 8"
    mask = codes != 0
    pos = jnp.cumsum(mask, axis=0) - 1                      # rank within col
    pos = jnp.where(mask, pos, keep_k)                      # park drops
    cols = jnp.broadcast_to(jnp.arange(N)[None, :], (K, N))
    values = jnp.zeros((keep_k, N), jnp.int8)
    values = values.at[pos, cols].set(codes, mode="drop")
    bits = mask.reshape(K // 8, 8, N).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))[None, :, None]
    bitmap = jnp.sum(bits * weights, axis=1).astype(jnp.uint8)
    return bitmap, values


def bitmap_unpack(bitmap: jax.Array, values: jax.Array) -> jax.Array:
    """Inverse of bitmap_pack -> dense int8 codes (K, N): one full-slab
    call of the kernels' shared expand tile (kernels/bitmap.py) — the
    format decode lives in exactly one place."""
    base = jnp.zeros((1, bitmap.shape[1]), jnp.int32)
    return expand_bitmap_tile(bitmap, values, base, values.shape[0])[0]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def dense_of(w, dtype=jnp.float32) -> jax.Array:
    """Dequantize any weight-leaf form back to a dense array.

    Used by paths that consume the weight *algebraically* rather than as a
    plain matmul (e.g. MLA's absorbed decode pulls k_up through q).  Cheap:
    the decode is elementwise and the consumers are small projections.
    """
    if isinstance(w, nn.Param):
        w = w.value
    if not isinstance(w, dict):
        return w.astype(dtype)
    return packed_codes(w).astype(dtype) * w["scale"].astype(dtype)


def packed_codes(w: dict) -> jax.Array:
    """Dense int8 codes of any packed weight leaf (bitmap forms expand —
    the jnp analogue of the in-VMEM expansion the sparse kernel does).
    The single source of truth for the per-mode storage keys.

    EVERY conv leaf (a ``geom`` entry rides the dict) is stored in the
    kernels' spatial-major tap layout at compile time — bitmap leaves
    additionally K-padded to a multiple of 8 (kernels/conv_sparse.py);
    this strips the pad and permutes back to the channel-major patch
    order every other consumer speaks.  NOT on the serving hot path —
    ``apply_conv`` hands the stored bytes straight to the kernel."""
    geom = w.get("geom")
    if geom is not None and geom.dw:   # depthwise leaf: tap-major (k*k, C)
        return w["values"]             # storage IS the canonical layout
    if "bitmap" in w:
        dense = bitmap_unpack(w["bitmap"], w["values"])
        if geom is not None:           # conv leaf: spatial-major, K padded
            kk = geom.c_in * geom.k * geom.k
            dense = kref.from_spatial_major(dense[:kk], geom.k, geom.c_in)
        elif "kdim" in w:              # linear leaf: strip the K%8 pad
            dense = dense[:w["kdim"].k]
        return dense
    dense = w.get("codes", w.get("bs_codes", w.get("values")))
    if geom is not None:               # dense conv leaf: spatial-major
        dense = kref.from_spatial_major(dense, geom.k, geom.c_in)
    return dense


def _flatten_batch(x: jax.Array):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


def _int8_dot(x_q: jax.Array, w_int8: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        x_q, w_int8, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def apply_linear(w, x: jax.Array, qat: bool = False,
                 per_row: bool = False) -> jax.Array:
    """y = x @ W for any compiled or dense weight leaf.  Preserves x.dtype.

    ``per_row=True`` quantizes each flattened input row under its own
    INT8 domain (scale per row of the (M, K) matmul input) instead of one
    tensor-wide scale — the compiled ResNet head uses this so a request's
    logits never depend on which rows share its microbatch (DESIGN.md §9).
    """
    if isinstance(w, nn.Param):
        w = w.value
    if not isinstance(w, dict):                    # dense (array / tracer)
        wv = w
        if qat:
            from repro.core.quantize import fake_quant_int7
            wv = fake_quant_int7(wv.astype(jnp.float32), axis=-1).astype(x.dtype)
        return jnp.matmul(x, wv.astype(x.dtype))

    # compiled conv leaves are stored spatial-major (bitmap ones also
    # K-padded) — silently wrong under a plain matmul; use apply_conv
    assert "geom" not in w, "compiled conv leaf: use apply_conv"
    x2, lead = _flatten_batch(x)
    x_q, s_x = act_quant(x2, per_row=per_row)
    if "bitmap" in w:                              # sparse_cfmm
        from repro.kernels import ops
        acc = ops.sparse_cfmm_matmul(x_q, w["bitmap"], w["values"])
    elif "bs_codes" in w:                          # bitserial ablation
        acc = cfmm.bitserial_matmul(x_q, w["bs_codes"])
    elif "codes" in w:                             # cfmm
        from repro.kernels import ops
        acc = ops.cfmm_matmul(x_q, w["codes"])
    else:                                          # int8
        acc = _int8_dot(x_q, w["values"])
    s_row = s_x.reshape(-1, 1) if per_row else s_x
    y = acc.astype(jnp.float32) * (s_row * w["scale"].reshape(1, -1))
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)


def conv_codes_of(w: dict):
    """Dense *channel-major* int8 codes + per-channel scale of a compiled
    conv leaf.

    Oracle/debug seam only: every conv leaf is stored spatial-major at
    compile time (bitmap leaves additionally packed), and this un-permutes
    (and expands) through ``packed_codes``.  The serving path never calls
    it — ``apply_conv`` hands the stored bytes straight to the kernels.
    ``bs_codes`` (bit-serial ablation) are bit-exact equal to plain codes
    as int8 operands, so they ride the MXU path too — the bit-plane loop
    remains a linear-layer-only ablation.
    """
    return packed_codes(w), w["scale"]


def apply_conv(w: dict, x_q: jax.Array, x_scale, *, gamma=None, beta=None,
               shortcut=None, relu: bool = True, quant_out: bool = False,
               zero_count: int | None = None):
    """Fused conv forward for a compiled conv leaf (carries its geometry).

    x_q (N, H, W, c_in) int8 + its scalar scale; gamma/beta are the
    folded-BN scale and bias Collector vectors.  Returns f32 NHWC, or
    (int8, scale) with quant_out (see kernels.ops.conv2d).
    ``zero_count`` opts into activation-sparsity profiling: the zero-count
    aux dict is appended to the return, observation-only (DESIGN.md §11).

    Dispatch rides the leaf's storage keys: ``bitmap`` leaves hand the
    packed (bitmap, values) pair straight to the bitmap-native sparse conv
    kernel — no expansion at the op boundary, HBM sees ~2.6 bits/param at
    s=0.8 — everything else feeds the dense-codes implicit-GEMM kernel.
    All conv leaves are stored in the kernels' spatial-major tap layout at
    compile time, so NO layout shuffle happens here or in ``ops.conv2d``
    (spy-tested in tests/test_conv.py).
    """
    geom = w["geom"]
    from repro.kernels import ops
    if geom.dw:                        # depthwise: tap-MAC kernel
        return ops.conv2d_dw(x_q, w["values"], geom.k, geom.stride,
                             x_scale=x_scale, w_scale=w["scale"],
                             gamma=gamma, beta=beta, shortcut=shortcut,
                             relu=relu, quant_out=quant_out,
                             zero_count=zero_count)
    if "bitmap" in w:                  # sparse_cfmm: packed weights only
        codes = (w["bitmap"], w["values"])
    else:
        codes = w.get("values", w.get("codes", w.get("bs_codes")))
    return ops.conv2d(x_q, codes, geom.k, geom.stride, x_scale=x_scale,
                      w_scale=w["scale"], gamma=gamma, beta=beta,
                      shortcut=shortcut, relu=relu, quant_out=quant_out,
                      w_layout="spatial", zero_count=zero_count)


# ---------------------------------------------------------------------------
# Compilation (training tree -> constant-parameter serving tree)
# ---------------------------------------------------------------------------

def _compile_leaf(p: nn.Param, mode: str, sparsity: float):
    w = p.value.astype(jnp.float32)
    lead, in_ax, out_ax = p.axes[:-2], p.axes[-2], p.axes[-1]
    dw = nn.dwconv_geom_of(p.kind)
    if dw is not None:
        # Depthwise leaves store dense tap-major int8 values in EVERY
        # serve mode: K = k*k (9 for the 3x3 mobilenet case), so a bitmap
        # or LUT re-encoding of 9 rows saves nothing and would only add a
        # per-tap decode to the VPU inner loop — the weight-bytes win of
        # sparse_cfmm lives in the pointwise convs that dominate
        # mobilenet's parameters, and those pack normally.
        assert w.ndim == 2, f"stacked depthwise leaves unsupported: {w.shape}"
        k, stride = dw
        assert w.shape[0] == k * k, (w.shape, p.kind)
        qt = quantize_int7(w, axis=-1)             # per-channel scale
        return {"values": nn.Param(qt.values, (in_ax, out_ax)),
                "scale": nn.Param(qt.scale.reshape(1, -1), (None, out_ax)),
                "geom": ConvGeom(k, stride, 1, dw=True)}
    geom = nn.conv_geom_of(p.kind)
    conv_k = geom[0] if geom is not None else None
    fn = lambda wi: _compile_leaf_2d(wi, mode, sparsity, conv_k)
    for _ in range(w.ndim - 2):                    # stacked (layers/experts)
        fn = jax.vmap(fn)
    out = fn(w)
    packed = {k: nn.Param(v, _leaf_axes(k, lead, in_ax, out_ax))
              for k, v in out.items()}
    if geom is not None:                           # conv weights stay
        k, stride = geom                           # self-describing
        packed["geom"] = ConvGeom(k, stride, w.shape[-2] // (k * k))
    elif mode == "sparse_cfmm" and w.shape[-2] % 8 != 0:
        packed["kdim"] = KDim(w.shape[-2])         # unpadded K (pad_rows8)
    return packed


def _leaf_axes(kind: str, lead, in_ax, out_ax):
    if kind == "scale":
        return lead + (None, out_ax)
    if kind == "bitmap":
        return lead + (in_ax, out_ax)    # rows = ceil(in/8) (K padded to %8)
    if kind == "values":
        return lead + (None, out_ax)
    return lead + (in_ax, out_ax)        # codes / bs_codes


def pad_rows8(codes: jax.Array) -> jax.Array:
    """Pad the K axis up to a multiple of 8 with all-zero (masked) rows —
    the bitmap K-padding rule.  Zero codes pack to zero bits, so the pad
    is invisible to the sparse kernels and exact under int8 matmul."""
    pad = (-codes.shape[0]) % 8
    if pad == 0:
        return codes
    return jnp.pad(codes, ((0, pad), (0, 0)))


def _compile_leaf_2d(w: jax.Array, mode: str, sparsity: float,
                     conv_k: int | None = None) -> dict:
    K = w.shape[0]
    if mode == "sparse_cfmm":
        keep_k = max(8, int(round(K * (1.0 - sparsity))))
        keep_k = min(K, ((keep_k + 7) // 8) * 8)
        qt = balanced_prune_codes(w, keep_k)
        codes = qt.values
        if conv_k is not None:
            # conv leaves pack in the kernels' spatial-major tap layout
            # (row = tap*c_in + c) so the packed pair feeds
            # kernels/conv_sparse.py with no boundary permute/expand
            codes = kref.to_spatial_major(codes, conv_k,
                                          K // (conv_k * conv_k))
        # K % 8 != 0 (e.g. the 7x7 stem, K = 3*49 = 147): pad + mask
        # instead of the old silent dense fallback
        bitmap, values = bitmap_pack(pad_rows8(codes), keep_k)
        return {"bitmap": bitmap, "values": values,
                "scale": qt.scale.reshape(1, -1)}
    qt = quantize_int7(w, axis=-1)
    codes = qt.values
    if conv_k is not None:
        # dense conv leaves store spatial-major too: the one weight-layout
        # shuffle runs here, at compile time, and ops.conv2d streams the
        # stored bytes with zero call-time permutes (per-column scales are
        # row-permutation-invariant, so the codes permute is free)
        codes = kref.to_spatial_major(codes, conv_k, K // (conv_k * conv_k))
    key = {"int8": "values", "bitserial": "bs_codes"}.get(mode, "codes")
    return {key: codes, "scale": qt.scale.reshape(1, -1)}


def compile_params(params, mode: str = "sparse_cfmm", sparsity: float = 0.8):
    """Convert a trained param tree to its Compiled-NN serving form.

    Only linear- and conv-kind leaves are packed; norms, embeddings, biases
    and routers stay in their training dtype.  Compiled conv leaves gain a
    static ``geom`` (k, stride, c_in) entry so the serving path needs no
    side-channel geometry.  Traceable — safe under jax.eval_shape for the
    dry run.
    """
    assert mode in SERVE_MODES, mode
    if mode == "dense":
        return params

    def visit(p):
        if isinstance(p, nn.Param) and nn.compilable(p.kind) \
                and p.value.ndim >= 2:
            return _compile_leaf(p, mode, sparsity)
        return p

    return jax.tree.map(visit, params, is_leaf=lambda x: isinstance(x, nn.Param))


def ensure_compiled(params, mode: str, sparsity: float):
    """The serving engines' front door: a boxed training tree compiles
    (and unboxes) to its constant-parameter form; an already-compiled
    unboxed tree passes through UNTOUCHED — callers may rely on the
    identity (``out is params``) to share one host-side tree across
    engines (serving/frontend.py does)."""
    boxed = any(isinstance(l, nn.Param) for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, nn.Param)))
    return nn.unbox(compile_params(params, mode=mode, sparsity=sparsity)) \
        if boxed else params

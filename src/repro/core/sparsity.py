"""Parameter sparsity utilities (paper SS II-A).

The paper uses an 80% unstructured-sparse ResNet50 (Movidius hybrid-pruned,
AMC-style) and exploits it at zero overhead because zero weights synthesize
to nothing.  On TPU, element sparsity in a dense MXU is worthless, so we
convert constant sparsity into forms the hardware can use:

* magnitude pruning to a target sparsity (the model-side substrate);
* bitmap-packed storage (values of nonzeros + 1 bit/elem mask) -> the
  memory-side win for weight-bandwidth-bound decode;
* column clustering -> block-level sparsity a tiled kernel can skip at
  trace time (weights are constants, so the block mask is compile-time).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero the smallest-|w| fraction globally (unstructured)."""
    if sparsity <= 0.0:
        return w
    flat = jnp.abs(w).reshape(-1)
    k = int(round(flat.size * sparsity))
    if k <= 0:
        return w
    thresh = jnp.sort(flat)[k - 1]
    return jnp.where(jnp.abs(w) > thresh, w, 0.0)


def sparsity_stats(q: jax.Array) -> dict:
    nz = np.asarray(jnp.sum(q != 0))
    total = int(np.prod(q.shape))
    return {"total": total, "nonzero": int(nz),
            "sparsity": 1.0 - int(nz) / max(total, 1)}


@dataclasses.dataclass
class BitmapPacked:
    """Bitmap-compressed constant weights (decode-bandwidth format).

    ``bitmap`` packs one validity bit per element (uint8, K/8 per column
    group); ``values`` holds int8 codes of nonzeros, padded to a fixed
    budget so shapes are static.  Storage for s-sparse INT7:
    (1-s)*8 + 1 bits/param  (~2.6 bits at 80% vs 16 for bf16 -> ~6.2x).
    """

    bitmap: np.ndarray        # (K // 8, N) uint8
    values: np.ndarray        # (budget, N) int8, column-major packed nonzeros
    nnz_per_col: np.ndarray   # (N,) int32
    shape: tuple[int, int]

    @property
    def packed_bytes(self) -> int:
        return self.bitmap.size + self.values.size + 4 * self.nnz_per_col.size

    @property
    def dense_bf16_bytes(self) -> int:
        return 2 * int(np.prod(self.shape))


def bitmap_pack(q_codes: np.ndarray, budget_slack: float = 1.0) -> BitmapPacked:
    """Pack int8 codes (K, N) column-wise.  budget = max col nnz * slack."""
    q = np.asarray(q_codes)
    K, N = q.shape
    assert K % 8 == 0, "K must be a multiple of 8 for bitmap packing"
    mask = (q != 0)
    nnz_per_col = mask.sum(axis=0).astype(np.int32)
    budget = int(np.ceil(nnz_per_col.max() * budget_slack)) if N else 0
    bits = mask.astype(np.uint8).reshape(K // 8, 8, N)
    weights = (1 << np.arange(8, dtype=np.uint8)).reshape(1, 8, 1)
    bitmap = (bits * weights).sum(axis=1).astype(np.uint8)
    values = np.zeros((budget, N), np.int8)
    for n in range(N):
        col = q[mask[:, n], n]
        values[: col.size, n] = col
    return BitmapPacked(bitmap, values, nnz_per_col, (K, N))


def bitmap_unpack(p: BitmapPacked) -> np.ndarray:
    K, N = p.shape
    bits = np.unpackbits(p.bitmap[:, None, :], axis=1, bitorder="little")
    mask = bits.reshape(K, N).astype(bool)
    q = np.zeros((K, N), np.int8)
    for n in range(N):
        q[mask[:, n], n] = p.values[: p.nnz_per_col[n], n]
    return q


def block_mask(q_codes: jax.Array, block: tuple[int, int]) -> np.ndarray:
    """(K/bk, N/bn) bool mask: True where a weight block has any nonzero.

    Weights are constants, so this mask is compile-time metadata — a tiled
    matmul specialises its grid to it (the paper's "MACs associated with
    constant zeros are simply dropped", at block granularity).
    """
    q = np.asarray(q_codes)
    K, N = q.shape
    bk, bn = block
    assert K % bk == 0 and N % bn == 0, (q.shape, block)
    blocks = q.reshape(K // bk, bk, N // bn, bn)
    return (blocks != 0).any(axis=(1, 3))


def block_sparsity(q_codes: jax.Array, block: tuple[int, int]) -> float:
    m = block_mask(q_codes, block)
    return 1.0 - float(m.mean())


def cluster_rows(q_codes: np.ndarray, block_k: int, iters: int = 8) -> np.ndarray:
    """Greedy row permutation concentrating nonzeros into row blocks.

    Orders rows by column-support similarity so that rows sharing support
    land in the same block of ``block_k`` — raising block sparsity that a
    trace-time-specialised kernel can skip.  Returns the permutation.
    """
    q = np.asarray(q_codes)
    K = q.shape[0]
    support = (q != 0)
    # Sort rows by (nnz, first-nonzero-column) then refine by nearest-
    # neighbour chaining on Jaccard similarity of supports.
    order = np.lexsort((support.argmax(axis=1), support.sum(axis=1)))
    sup = support[order]
    perm = list(range(K))
    for _ in range(iters):
        improved = False
        for i in range(0, K - block_k, block_k):
            a = sup[perm[i: i + block_k]].any(axis=0)
            j_block = i + block_k
            b = sup[perm[j_block: j_block + block_k]].any(axis=0)
            base = a.sum() + b.sum()
            # try swapping boundary rows to shrink combined support
            ii, jj = i + block_k - 1, j_block
            if jj < len(perm):
                perm[ii], perm[jj] = perm[jj], perm[ii]
                a2 = sup[perm[i: i + block_k]].any(axis=0)
                b2 = sup[perm[j_block: j_block + block_k]].any(axis=0)
                if a2.sum() + b2.sum() < base:
                    improved = True
                else:
                    perm[ii], perm[jj] = perm[jj], perm[ii]
        if not improved:
            break
    return order[np.asarray(perm)]


def effective_ops(q_codes: jax.Array, macs_dense: int) -> dict:
    """Paper's "effective TOPs" accounting: ops are counted dense (sparsity
    is a benefit, so effective ops = dense MACs * 2) while the hardware only
    executes the nonzero fraction."""
    stats = sparsity_stats(q_codes)
    executed = macs_dense * (1.0 - stats["sparsity"])
    return {
        "effective_ops": 2 * macs_dense,
        "executed_macs": executed,
        "speedup_vs_dense": macs_dense / max(executed, 1.0),
        **stats,
    }

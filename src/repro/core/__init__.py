from repro.core import cfmm, compiled_linear, fpga_model, partition, quantize, sparsity  # noqa: F401

"""FPGA resource/throughput cost model — reproduces Table II and feeds Fig 7.

We have no Stratix-10 toolchain here, so (exactly like the paper's own
projection methodology: "we use the demonstrated implementation results to
estimate the resource requirements for the remaining convolution layers")
we build an analytical ALM/frequency model from the paper's published
constants and calibrate its one free coefficient (adder-tree ALMs per
nonzero weight, absorbing routing overhead) against the Table II conv2
corner.  The model then *predicts* the other corner and the paper's design
decisions; benchmarks/table2 asserts these reproductions:

  * conv5_2 must fold 4x to fit/balance          (paper SS III.1)
  * conv2_2 needs 8 instances (2 kernels x 4)    (paper SS III.1)
  * conv5 kernel ALMs ~620k with 2x CFMM dupes   (Table II)

Paper constants encoded:
  * CFMM block ~30 ALMs: 32 unique odd products, one incremental add/sub
    each, x1 and even-shifts free                               (SS II-E.1)
  * 6:3 carry-hiding reduction, 3 ALMs asymptotic (the calibrated
    ALM/nnz coefficient includes pipelining + routing overhead) (SS II-E.2)
  * bit-serial: ~(act_bits + log2(adder tree depth)) clocks per conv step
  * folding: one mux per implemented product                    (SS II-E.3)
"""
from __future__ import annotations

import dataclasses

import numpy as np

CFMM_ALMS = 30                 # per CFMM block (one per IFM lane)
UNIQUE_PRODUCTS = 32           # INT7 -> 32 odd magnitudes
SPARSITY = 0.80                # Movidius proxy model
ACT_BITS = 8                   # activations rounded to 8 bits


@dataclasses.dataclass(frozen=True)
class FPGASpec:
    name: str
    alms: int
    dsps: int
    m20ks: int

    def usable_alms(self, utilization: float) -> float:
        return self.alms * utilization


# Stratix 10 GX 2800 ("GX280") and the DSP-light "GX550" (~2x ALMs at the
# same performance density, per the paper's own projection ratio 131/66).
GX280 = FPGASpec("GX280", 933_120, 5_760, 11_721)
GX550 = FPGASpec("GX550", 1_866_240, 1_980, 23_442)

# Table II (measured corners) — calibration + reproduction targets.
TABLE2_ACTUAL = {
    "conv2": dict(instances=4, folding=1, freq_mhz=353, alm_per_kernel=127_000,
                  dsp_per_kernel=96, m20k_per_kernel=1852, mops_per_alm=70,
                  gx280_tops=66, gx550_tops=131, chip_util=0.76, kernels_on_chip=5),
    "conv5": dict(instances=1, folding=4, freq_mhz=156, alm_per_kernel=620_000,
                  dsp_per_kernel=256, m20k_per_kernel=1100, mops_per_alm=12,
                  gx280_tops=12, gx550_tops=23, chip_util=0.67, kernels_on_chip=1),
}
FIG7 = dict(im_s_total=53_061, batch=2, max_link_gbps=75,
            im_s_per_chip_gx280=5_896, im_s_per_chip_gx550=10_612,
            v100_im_s=1_544, v100_sparse_bound=7_720, speedup_vs_v100=1.37)


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    """One convolution layer of the network."""

    name: str
    c_in: int
    c_out: int
    k: int                     # filter size
    hw: int                    # output feature-map height == width
    stride: int = 1

    @property
    def params(self) -> int:
        return self.c_in * self.c_out * self.k * self.k

    @property
    def macs(self) -> int:
        return self.params * self.hw * self.hw

    @property
    def mac_per_param(self) -> int:
        return self.hw * self.hw

    @property
    def nnz(self) -> float:
        return self.params * (1.0 - SPARSITY)

    @property
    def out_bytes(self) -> int:
        return self.hw * self.hw * self.c_out  # 8-bit activations


def serial_cycles(layer: ConvLayerSpec) -> float:
    """Bit-serial clocks per conv step: operand bits + accumulator guard."""
    inputs_per_ofm = max(2.0, layer.nnz / layer.c_out)
    return ACT_BITS + np.log2(inputs_per_ofm)


def kernel_alms(layer: ConvLayerSpec, fold: int = 1, instances: float = 1,
                alm_per_nnz: float | None = None, cfmm_dupe: int = 1) -> float:
    """ALMs for one Kernel module (``instances`` conv steps, TDM ``fold``)."""
    if alm_per_nnz is None:
        alm_per_nnz = _CAL["alm_per_nnz"]
    nnz_impl = layer.nnz / fold
    cfmm = layer.c_in * CFMM_ALMS * cfmm_dupe
    tree = alm_per_nnz * nnz_impl
    mux = nnz_impl if fold > 1 else 0.0
    return instances * (cfmm + tree + mux)


def freq_model(alm_per_kernel: float) -> float:
    """Routability-limited frequency vs whole-kernel size.

    Calibrated through both Table II corners: 127k ALMs -> 353 MHz (conv2's
    4-instance kernel) and 620k -> 156 MHz (conv5's folded kernel).
    """
    a2, a5 = TABLE2_ACTUAL["conv2"], TABLE2_ACTUAL["conv5"]
    k2 = a2["alm_per_kernel"]
    exp = (np.log(a5["freq_mhz"] / a2["freq_mhz"])
           / np.log(a5["alm_per_kernel"] / k2))
    f = a2["freq_mhz"] * (max(alm_per_kernel, 1.0) / k2) ** exp
    return float(np.clip(f, 100.0, 450.0))


def plan_layer(layer: ConvLayerSpec, target_im_s: float,
               cfmm_dupe: int | None = None, chip: FPGASpec = GX280,
               util_target: float = 0.76) -> dict:
    """Size one layer's Kernel for a target throughput (paper SS II-E.3).

    One instance retires one conv step (all post-prune MACs for one output
    position) every serial_cycles clocks.  instances_needed < 1 -> fold
    (TDM); > 1 -> multi-instance kernels.  Folding is additionally forced
    until a single kernel fits on one chip (the paper's "conv5_2 must be
    folded by 4x to fit on GX280" is fit-driven, not throughput-driven).
    """
    base_alm = kernel_alms(layer, 1, 1)
    cyc = serial_cycles(layer)
    steps_per_s = target_im_s * layer.hw * layer.hw
    if cfmm_dupe is None:
        cfmm_dupe = 2 if base_alm > 400_000 else 1  # routing congestion
    cap = chip.usable_alms(util_target)
    freq = freq_model(min(base_alm, cap))
    fold = instances = 1
    for _ in range(3):  # fixed point: fold/instances <-> routed frequency
        inst = steps_per_s * cyc / (freq * 1e6)
        if inst >= 1.0:
            fold, instances = 1, int(np.ceil(inst))
        else:
            fold, instances = min(max(int(np.ceil(1.0 / inst)), 1), 16), 1
        # fit-driven folding: one kernel must fit the chip's usable fabric
        while (kernel_alms(layer, fold, 1, cfmm_dupe=cfmm_dupe) > cap
               and fold < 64):
            fold += max(1, fold // 2)
        freq = freq_model(kernel_alms(layer, fold, min(instances, 4),
                                      cfmm_dupe=cfmm_dupe))
    alms = kernel_alms(layer, fold, instances, cfmm_dupe=cfmm_dupe)
    im_s_capable = instances * freq * 1e6 / (cyc * layer.hw * layer.hw * fold)
    eff_tops = 2.0 * layer.macs * min(target_im_s, im_s_capable) / 1e12
    return dict(layer=layer.name, params=layer.params, nnz=int(layer.nnz),
                freq_mhz=freq, serial_cycles=cyc, instances=instances,
                fold=fold, alms=alms, eff_tops=eff_tops,
                im_s_capable=im_s_capable,
                mops_per_alm=eff_tops * 1e12 / alms / 1e6,
                out_bytes=layer.out_bytes)


def _calibrate() -> dict:
    c2 = ConvLayerSpec("conv2_2_3x3", 64, 64, 3, 56)
    t2 = TABLE2_ACTUAL["conv2"]
    a = (t2["alm_per_kernel"] / t2["instances"] - c2.c_in * CFMM_ALMS) / c2.nnz
    return {"alm_per_nnz": float(a)}


_CAL = _calibrate()


def table2_model() -> dict:
    """Model vs Table II actuals (printed/asserted by benchmarks/table2)."""
    corners = {
        "conv2": ConvLayerSpec("conv2_2_3x3", 64, 64, 3, 56),
        "conv5": ConvLayerSpec("conv5_2_3x3", 512, 512, 3, 7),
    }
    out = {"calibration": dict(_CAL)}
    for name, layer in corners.items():
        act = TABLE2_ACTUAL[name]
        plan = plan_layer(layer, FIG7["im_s_total"])
        # effective TOPs of the as-built kernel at its achieved frequency
        dense_ops_per_step = 2.0 * layer.params
        ktops = (plan["instances"] * dense_ops_per_step * act["freq_mhz"] * 1e6
                 / (plan["serial_cycles"] * plan["fold"]) / 1e12)
        mops_per_alm = ktops * 1e12 / plan["alms"] / 1e6
        # Table II reports conv2 kernels as 4-instance modules; and chip
        # TOPs as density x total fabric (66e12/933k == 70 MOPs/ALM).
        rep_inst = min(plan["instances"], act["instances"])
        alm_per_rep_kernel = plan["alms"] / plan["instances"] * rep_inst
        gx280_tops = mops_per_alm * 1e6 * GX280.alms / 1e12
        out[name] = dict(
            layer=layer.name, params=layer.params, nnz=plan["nnz"],
            serial_cycles=plan["serial_cycles"],
            model=dict(instances_total=plan["instances"],
                       instances_per_kernel=rep_inst, fold=plan["fold"],
                       alm_per_kernel=alm_per_rep_kernel,
                       freq_mhz=plan["freq_mhz"],
                       kernel_tops=ktops, gx280_tops=gx280_tops,
                       gx550_tops=gx280_tops * GX550.alms / GX280.alms,
                       mops_per_alm=mops_per_alm),
            actual={k: act[k] for k in ("instances", "folding", "freq_mhz",
                                        "alm_per_kernel", "mops_per_alm",
                                        "gx280_tops", "gx550_tops",
                                        "chip_util")},
        )
    return out

"""Open-loop load generation for the serving fleet.

Every bench before this one was *closed-loop*: submit a wave, drain it,
measure.  Closed loops flatter a server — offered load automatically
throttles to service rate, so the queue can never run away.  Production
traffic is OPEN loop: arrivals are a Poisson process that does not care
how busy the fleet is, and the front door must hold (queue) or refuse
(shed) what the replicas cannot absorb.  This module manufactures that
traffic deterministically:

* ``poisson_plan`` draws exponential inter-arrival gaps at a target
  request rate plus a request-size mix (the "millions of users" traffic
  is mostly 1-image requests with a heavier tail), seeded, with every
  request's images taken as a contiguous row slice of a caller-provided
  pool — so the bit-identity reference for any request is just
  ``reference_logits`` over the same slice.
* ``run_open_loop`` replays a plan against a ``ResNetFrontend`` in wall
  time: submit every arrival whose time has come, step the fleet,
  sleep only when genuinely idle, and classify each submit outcome by
  its type (``Admitted`` vs ``Rejected`` — the SLO admission surface).

``benchmarks/frontend_bench.py`` sweeps offered load as multiples of the
fleet's measured capacity and records the latency-vs-offered-load curve
(plus shed fraction) to BENCH_frontend.json.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serving.frontend import FrontendRequest, Rejected


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One planned request: submit at ``t`` seconds after wave start."""
    t: float
    req: FrontendRequest


def poisson_plan(*, rate_rps: float, n_requests: int,
                 image_pool: np.ndarray,
                 size_mix=((1, 1.0),), seed: int = 0,
                 rid_base: int = 0) -> list:
    """A deterministic open-loop arrival plan: ``n_requests`` requests
    with exponential inter-arrival gaps at ``rate_rps`` requests/s and
    row counts drawn from ``size_mix`` (pairs of ``(rows, weight)``).
    Each request's images are a contiguous slice of ``image_pool``
    (shape ``(P, H, W, 3)``), so its logits reference is cheap to
    compute and bit-comparisons stay trivial.  Same seed, same plan."""
    assert rate_rps > 0 and n_requests >= 0, (rate_rps, n_requests)
    sizes = np.asarray([s for s, _ in size_mix], dtype=int)
    weights = np.asarray([w for _, w in size_mix], dtype=float)
    assert (sizes >= 1).all() and (weights > 0).all(), size_mix
    assert sizes.max() <= len(image_pool), (sizes.max(), len(image_pool))
    weights = weights / weights.sum()
    rng = np.random.RandomState(seed)
    t, plan = 0.0, []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        n = int(sizes[rng.choice(len(sizes), p=weights)])
        off = int(rng.randint(0, len(image_pool) - n + 1))
        plan.append(Arrival(t, FrontendRequest(
            rid=rid_base + i, images=image_pool[off:off + n])))
    return plan


def offered_rows_per_s(plan: list) -> float:
    """The plan's offered load in rows/s (total rows over the arrival
    horizon) — what capacity multiples are computed against."""
    if not plan:
        return 0.0
    rows = sum(len(a.req.images) for a in plan)
    horizon = max(a.t for a in plan)
    return rows / horizon if horizon > 0 else float("inf")


def run_open_loop(frontend, plan: list, *, max_wall_s: float | None = None,
                  clock=time.perf_counter) -> dict:
    """Replay ``plan`` against ``frontend`` in wall time and drain.

    Arrivals are submitted the moment their time comes — regardless of
    fleet load, that is what "open loop" means — and classified by the
    typed submit outcome.  The fleet steps continuously while busy and
    sleeps in short slices when idle between arrivals.  Returns the
    admitted/rejected request lists plus wall-clock, goodput, and
    latency aggregates (latencies from the requests' own submit→done
    stamps).  ``max_wall_s`` is the last-resort guard: a fleet that
    cannot drain the admitted work raises TimeoutError."""
    plan = sorted(plan, key=lambda a: a.t)
    admitted, rejected = [], []
    tel = getattr(frontend, "telemetry", None)
    tr = tel.trace if tel is not None else None
    t0 = clock()
    i = 0
    while True:
        now = clock() - t0
        while i < len(plan) and plan[i].t <= now:
            if tr is not None:
                # the arrival instant (the shed instant, if any, comes
                # from submit itself)
                tr.instant("arrival", "loadgen", 0, plan[i].req.rid,
                           rid=plan[i].req.rid,
                           rows=len(plan[i].req.images),
                           planned_t_s=plan[i].t)
            out = frontend.submit(plan[i].req)
            (rejected if isinstance(out, Rejected)
             else admitted).append(plan[i].req)
            i += 1
        busy = frontend.step()
        if i >= len(plan) and not busy:
            break
        now = clock() - t0
        if not busy and i < len(plan) and plan[i].t > now:
            time.sleep(min(plan[i].t - now, 0.005))
        if max_wall_s is not None and now > max_wall_s:
            err = TimeoutError(
                f"open-loop wave exceeded max_wall_s={max_wall_s} with "
                f"{i}/{len(plan)} arrivals submitted")
            err.fleet_stats = frontend.stats()
            raise err
    wall = clock() - t0
    lats = [r.latency_s for r in admitted if r.latency_s is not None]
    rows_admitted = sum(len(r.images) for r in admitted)
    return {
        "offered": len(plan),
        "offered_rows": sum(len(a.req.images) for a in plan),
        "admitted": len(admitted),
        "rejected": len(rejected),
        "shed_fraction": len(rejected) / len(plan) if plan else 0.0,
        "wall_s": wall,
        "goodput_rows_s": rows_admitted / wall if wall > 0 else None,
        "latency_p50_s": (float(np.percentile(lats, 50)) if lats else None),
        "latency_p95_s": (float(np.percentile(lats, 95)) if lats else None),
        "admitted_requests": admitted,
        "rejected_requests": rejected,
    }

"""Pipeline-parallel conv-DAG serving engine — persistent per-stage
weights, microbatched requests, the executable Fig 7.  Serves any model
exposing the zoo protocol (``cfg.graph()``/``cfg.apply()`` —
DESIGN.md §12): ResNet50, MobileNetV2, fused RepVGG.

Mirrors ``serving/engine.py``'s submit/step/run surface for the CNN path:
requests carry image batches, the engine splits them into rows, and a
``distributed.conv_pipeline.ConvPipeline`` rotates microbatches through
per-device stages whose (disjoint) constant weights were placed at
construction time.

Stage planning accepts, in precedence order:

* ``plan``        — explicit ``partition.StagePlan`` list (or a
                    ``PartitionResult``, re-balanced to the device count);
* ``stage_blocks``— an explicit stage map: tuple of block-id tuples;
* ``n_stages``    — MAC-balanced contiguous split (partition.plan_stages).

Quantization domains are PER ROW (per image, DESIGN.md §9): every edge of
the compiled forward carries ``(int8, scale[row])``, so one row's logits
depend only on its own pixels — never on whoever shares its microbatch.
That is what makes **continuous cross-request batching** sound: the
engine packs rows from *different* requests into one microbatch
(``_next_microbatch``), keeping the pipe full under heavy small-request
traffic, and every request is still bit-identical to the per-row
single-device reference (``reference_logits``) for ANY packing,
``pack_requests`` setting, stage count, or arrival order.  Each injected
microbatch carries per-row segment tags ``(request, start_row, n_rows)``
so completed logits scatter back to their requests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.compiled_linear import ensure_compiled
from repro.distributed.conv_pipeline import ConvPipeline, PipelineStage
from repro.models.graph import compile_graph
from repro.obs.metrics import LIFE, MetricsRegistry


@dataclasses.dataclass
class PipelineRequest:
    rid: int
    images: np.ndarray                  # (n, H, W, 3) f32
    logits: np.ndarray | None = None
    rows_submitted: int = 0
    rows_done: int = 0
    done: bool = False


@dataclasses.dataclass
class _RowSpan:
    """A contiguous row range of one request waiting in the engine queue.

    ``cursor`` advances as rows enter microbatches; the span is spent when
    it reaches ``stop``.  Whole-request submission makes one span; the
    row-granular front door (serving/frontend.py) may enqueue several
    spans of one request — possibly on different replicas — and per-row
    quantization domains keep every split bit-identical.
    """

    req: PipelineRequest
    cursor: int
    stop: int

    @property
    def remaining(self) -> int:
        return self.stop - self.cursor


def _make_stage_fn(unit_fns, profiled: bool = False):
    if profiled:
        # profiled units return (carry, aux); the stage program merges
        # its units' aux dicts (layer names are globally unique) and the
        # pipe feeds them to telemetry.sparsity — still one jit per stage
        def stage_fn(stage_params, carry):
            aux = {}
            for fn, p in zip(unit_fns, stage_params):
                carry, a = fn(p, carry)
                aux.update(a)
            return carry, aux
    else:
        def stage_fn(stage_params, carry):
            for fn, p in zip(unit_fns, stage_params):
                carry = fn(p, carry)
            return carry
    return jax.jit(stage_fn)


def reference_logits(params, cfg, x, microbatch: int):
    """The single-device compiled path at microbatch granularity — the
    bit-identity reference for every stage count AND every packing of
    rows into microbatches: quantization domains are per-row, so the
    microbatch split here is a memory bound, not a numerics choice.

    Jitted, like the engine's stage programs: slicing the unit list into
    jitted stages is bit-exact vs the whole-model jit (no float op's
    fusion pair spans an int8 edge), whereas op-by-op eager execution
    differs by FMA-contraction ulps from ANY jitted lowering."""
    if x.shape[0] == 0:
        # zero-row input: jnp.concatenate over no microbatches would
        # raise — return the empty logits directly
        return jnp.zeros((0, cfg.num_classes), jnp.float32)
    fn = jax.jit(lambda p, mb: cfg.apply(p, mb))
    mbs = [x[i:i + microbatch] for i in range(0, x.shape[0], microbatch)]
    return jnp.concatenate([fn(params, mb) for mb in mbs])


def reference_profile(params, cfg, x, microbatch: int, groups: int,
                      lowering: str | None = None):
    """Single-device activation-sparsity oracle: run the PROFILED
    compiled units over ``x`` at microbatch granularity and return
    ``(logits, SparsityProfiler snapshot)``.

    ``lowering`` temporarily pins ``REPRO_PALLAS`` (e.g. ``"jnp"`` for
    the exact recount oracle the telemetry bench compares the serving
    path's histograms against); the jitted chain is built fresh here, so
    the pin takes effect for this call's tracing regardless of what the
    process served with before.  ``params`` must already be compiled
    (``ensure_compiled``)."""
    import os
    from repro.obs.sparsity import SparsityProfiler
    prof = SparsityProfiler(groups=groups)
    units = compile_graph(cfg.graph(), params, sparsity_groups=groups)
    unit_fns = tuple(u.fn for u in units)
    unit_ps = tuple(u.params for u in units)

    def chain(ps, mb):
        aux_all = {}
        for f, p in zip(unit_fns, ps):
            mb, aux = f(p, mb)
            aux_all.update(aux)
        return mb, aux_all

    jfn = jax.jit(chain)
    old = os.environ.get("REPRO_PALLAS")
    if lowering is not None:
        os.environ["REPRO_PALLAS"] = lowering
    try:
        outs = []
        for i in range(0, x.shape[0], microbatch):
            out, aux = jfn(unit_ps, jnp.asarray(x[i:i + microbatch],
                                                jnp.float32))
            prof.add(aux)
            outs.append(np.asarray(out))
    finally:
        if lowering is not None:
            if old is None:
                os.environ.pop("REPRO_PALLAS", None)
            else:
                os.environ["REPRO_PALLAS"] = old
    logits = (np.concatenate(outs) if outs
              else np.zeros((0, cfg.num_classes), np.float32))
    return logits, prof.snapshot()


class PipelineEngine:
    """Persistent pipeline-parallel serving of any compiled conv-DAG.

    ``cfg`` is any model config exposing the zoo protocol — ``graph()``
    (a ``models.graph.Graph``), ``apply(params, x)``, and
    ``num_classes`` — e.g. ``ResNetConfig``, ``MobileNetV2Config``, or
    ``RepVGGConfig`` (fused params).  The engine compiles the graph into
    pipeline units and plans stages from the graph's own per-unit conv
    specs and cut-edge byte counts."""

    def __init__(self, cfg, params, *,
                 mode: str = "int8", sparsity: float = 0.8,
                 n_stages: int | None = None, stage_blocks=None, plan=None,
                 microbatch: int = 2, devices=None, replica: int = 0,
                 pack_requests: bool = True, telemetry=None):
        assert mode != "dense", "the pipeline serves the compiled network"
        self.cfg = cfg
        self.microbatch = microbatch
        # continuous cross-request batching: fill microbatches across
        # request boundaries (sound under per-row quantization domains).
        # False restores whole-request microbatch packing — kept as the
        # measurable baseline for benchmarks/frontend_bench.py.
        self.pack_requests = pack_requests
        # params: the boxed training tree (compiled here, like
        # ServingEngine) or an already-compiled unboxed tree
        self.params = ensure_compiled(params, mode, sparsity)
        self.telemetry = telemetry
        # one registry per engine; the pipe shares it so engine+pipe
        # export as one snapshot() surface
        self.metrics = MetricsRegistry()
        self._mb_injected = self.metrics.counter("engine.mb_injected")
        self._rows_injected = self.metrics.counter("engine.rows_injected")
        # lifetime odometer (LIFE scope: survives reset_counters, unlike
        # the wave counters): rows delivered back to requests — the
        # front door differences it to estimate fleet service rate, and
        # the watchdog folds it into progress_marker
        self._rows_completed = self.metrics.counter(
            "engine.rows_completed", scope=LIFE)
        # activation-sparsity profiling compiles DIFFERENT stage
        # programs (units return (carry, aux)); off by default
        groups = (telemetry.sparsity.groups
                  if telemetry is not None and telemetry.profiled else None)
        self.graph = cfg.graph()
        units = compile_graph(self.graph, self.params,
                              sparsity_groups=groups)
        self._profiled = groups is not None
        n_blocks = len(units) - 1              # head rides the last stage
        self.plan = self._resolve_plan(plan, stage_blocks, n_stages,
                                       n_blocks, devices)
        self.stage_block_ids = [p.block_ids for p in self.plan]
        devices = self._resolve_devices(devices, len(self.plan))
        self.pipe = ConvPipeline(
            self._build_stages(units, self.stage_block_ids, devices),
            replica=replica, metrics=self.metrics, telemetry=telemetry)
        self.queue: list[_RowSpan] = []
        # incremental row accounting (kept exactly in sync with the span
        # queue; _scan_pending_rows is the O(queue) oracle tests assert
        # against) — pending_rows is O(1) so the front door's routing
        # loop stays linear in admitted requests
        self._queued_rows = 0
        self._rows_in_flight = 0
        # host-dispatch-gap hint for bubble attribution: rows the FRONT
        # DOOR holds undispatched (the frontend refreshes this every
        # step; standalone engines leave it 0)
        self.door_rows = 0

    @property
    def rows_completed(self) -> int:
        return self._rows_completed.value

    # -- stage planning -------------------------------------------------
    def _resolve_plan(self, plan, stage_blocks, n_stages, n_blocks,
                      devices):
        blocks = self.graph.blocks()
        edge_bytes = self.graph.edge_bytes()
        assert len(blocks) == n_blocks, (len(blocks), n_blocks)
        if isinstance(plan, partition.PartitionResult):
            want = n_stages or (len(devices) if devices else None)
            return plan.stage_plans(blocks, want, edge_bytes)
        if plan is not None:                   # explicit StagePlan list
            return list(plan)
        if stage_blocks is not None:           # explicit stage map
            return partition.explicit_stage_plans(blocks, stage_blocks,
                                                  edge_bytes)
        return partition.plan_stages(blocks, n_stages or 1, edge_bytes)

    @staticmethod
    def _resolve_devices(devices, n_stages):
        if devices is None:
            from repro.launch.mesh import pipeline_stage_devices
            devices = pipeline_stage_devices(n_stages)
        assert len(devices) >= n_stages, (len(devices), n_stages)
        return list(devices[:n_stages])

    def _build_stages(self, units, stage_block_ids, devices):
        covered = [b for ids in stage_block_ids for b in ids]
        assert covered == list(range(len(units) - 1)), (
            "stage map must cover blocks 0..%d contiguously" % (len(units) - 2),
            stage_block_ids)
        stages = []
        for s, ids in enumerate(stage_block_ids):
            mine = [u for u in units if u.block_id in ids]
            if s == len(stage_block_ids) - 1:
                mine.append(units[-1])         # the head
            # the stage's device holds ONLY these units' constant weights
            stage_params = jax.device_put(
                tuple(u.params for u in mine), devices[s])
            stages.append(PipelineStage(
                index=s, device=devices[s],
                fn=_make_stage_fn(tuple(u.fn for u in mine),
                                  profiled=self._profiled),
                params=stage_params,
                unit_names=tuple(u.name for u in mine)))
        return stages

    # -- request management --------------------------------------------
    def submit(self, req: PipelineRequest):
        """Enqueue a whole request (resets its lifecycle)."""
        req.logits = None
        req.rows_submitted = req.rows_done = 0
        req.done = False
        self.queue.append(_RowSpan(req, 0, len(req.images)))
        self._queued_rows += len(req.images)

    def submit_rows(self, req: PipelineRequest, start: int, stop: int):
        """Enqueue one row span of a request WITHOUT touching its
        lifecycle — the front door's row-granular dispatch path: a
        request's rows may be spread over several spans (even on
        different replicas) and per-row quantization domains keep every
        split bit-identical.  The caller owns the lifecycle reset."""
        assert 0 <= start <= stop <= len(req.images), (
            start, stop, len(req.images))
        self.queue.append(_RowSpan(req, start, stop))
        self._queued_rows += stop - start

    @staticmethod
    def _complete_empty(req, num_classes):
        req.logits = np.zeros((0, num_classes), np.float32)
        req.done = True

    def _next_microbatch(self):
        """Pack up to ``microbatch`` head-of-queue rows into one
        microbatch.  With ``pack_requests`` (the default) rows from
        DIFFERENT requests share a microbatch — continuous batching,
        sound because quantization domains are per-row; otherwise a
        microbatch stops at the first span boundary (the whole-request
        baseline).  Returns (segments, rows): segments are per-row
        request tags ``(request, start_row, n_rows)`` in row order."""
        segs, parts = [], []
        need = self.microbatch
        while self.queue and need > 0:
            span = self.queue[0]
            if span.remaining == 0:            # zero-row request: complete
                if len(span.req.images) == 0:
                    self._complete_empty(span.req, self.cfg.num_classes)
                self.queue.pop(0)
                continue
            take = min(need, span.remaining)
            segs.append((span.req, span.cursor, take))
            parts.append(span.req.images[span.cursor:span.cursor + take])
            span.cursor += take
            span.req.rows_submitted += take
            self._queued_rows -= take
            need -= take
            if span.remaining == 0:
                self.queue.pop(0)
            if not self.pack_requests:
                break                          # never cross a span boundary
        if not segs:
            return None, None
        rows = parts[0] if len(parts) == 1 else np.concatenate(parts)
        return segs, jnp.asarray(rows, jnp.float32)

    def step(self) -> bool:
        """Inject one microbatch (if any rows are queued) and advance the
        schedule one tick; completed rows scatter back to their segments'
        requests.  Returns False once idle."""
        tag = mb = None
        if self.pipe.inlet_free:
            tag, mb = self._next_microbatch()
        if mb is None and not self.pipe.busy:
            return False
        if mb is not None:
            self._rows_in_flight += int(mb.shape[0])
            self._mb_injected.inc()
            self._rows_injected.inc(int(mb.shape[0]))
        self.pipe.door_rows = self.door_rows
        for segs, out in self.pipe.tick(inject=mb, tag=tag):
            out = np.asarray(out)
            off = 0
            for req, start, n in segs:
                if req.logits is None:
                    req.logits = np.zeros((len(req.images), out.shape[-1]),
                                          out.dtype)
                req.logits[start:start + n] = out[off:off + n]
                req.rows_done += n
                req.done = req.rows_done >= len(req.images)
                off += n
            assert off == out.shape[0], (off, out.shape)
            self._rows_in_flight -= out.shape[0]
            self._rows_completed.inc(int(out.shape[0]))
        return True

    def run(self, requests: list) -> list:
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return requests

    @property
    def pending_rows(self) -> int:
        """Rows accepted but not yet delivered: the queue's unsubmitted
        rows plus the exact rows still rotating through the stages
        (partial microbatches count their real size) — the load metric
        ``serving.frontend.ResNetFrontend``'s least-loaded router
        compares across replicas.  O(1): incrementally maintained (the
        router reads it once per admitted row chunk, so a linear scan
        here made dispatch O(requests²) under load — tests assert it
        equals ``_scan_pending_rows``)."""
        return self._queued_rows + self._rows_in_flight

    def _scan_pending_rows(self) -> int:
        """The linear-scan oracle for ``pending_rows`` (tests only)."""
        return sum(sp.remaining for sp in self.queue) + self._rows_in_flight

    # -- health surface (consumed by serving/frontend.py) ----------------
    @property
    def progress_marker(self) -> tuple:
        """A snapshot that changes on EVERY healthy busy step: rows
        delivered, rows queued, rows in flight, and the stage-inlet
        occupancy pattern (a microbatch advancing one stage flips two
        cells even when the aggregate counts hold still, e.g. one
        microbatch traversing a deep pipe).  The front door's watchdog
        marks a replica failed when this freezes for ``watchdog_ticks``
        steps while ``pending_rows``/``pipe.busy`` say it has work
        (DESIGN.md §10)."""
        return (self.rows_completed, self._queued_rows,
                self._rows_in_flight, self.pipe.inlet_occupancy)

    def extract_pending(self) -> list:
        """Cancel everything this engine still owes and return it as
        ``(request, start, stop)`` row spans — the drain half of replica
        failure recovery.  Covers both the un-injected queue spans and
        the rows buffered in stage inlets (via
        ``ConvPipeline.cancel_in_flight``; their ``rows_submitted`` is
        rewound so re-execution accounting starts clean).  Rows that
        already scattered back to their requests are NOT extracted —
        they were delivered by the same program every replica runs, and
        per-row quantization domains make the re-executed remainder
        bit-identical to the never-failed reference (DESIGN.md §9/§10).
        Leaves the engine idle: empty queue, empty inlets, zeroed
        pending-row accounting."""
        spans = []
        for segs in self.pipe.cancel_in_flight():
            for req, start, n in segs:
                req.rows_submitted -= n
                spans.append((req, start, start + n))
        self._rows_in_flight = 0
        for sp in self.queue:
            if sp.remaining:
                spans.append((sp.req, sp.cursor, sp.stop))
            elif len(sp.req.images) == 0 and not sp.req.done:
                # a queued zero-row request completes here, as
                # _next_microbatch would have
                self._complete_empty(sp.req, self.cfg.num_classes)
        self.queue.clear()
        self._queued_rows = 0
        return spans

    def run_batch(self, x) -> jnp.ndarray:
        """Convenience: one anonymous request, returns stacked logits."""
        req = PipelineRequest(rid=-1, images=np.asarray(x))
        self.run([req])
        return jnp.asarray(req.logits)

    def reset_counters(self):
        """Zero the wave-scoped schedule + occupancy counters (idle only
        — delegates the busy check to ConvPipeline.reset_counters); the
        lifetime ``rows_completed`` odometer is LIFE-scoped and
        survives."""
        self.pipe.reset_counters()
        self.metrics.reset_wave()

    def snapshot(self) -> dict:
        """The registry behind ``stats()``: every engine + pipe metric
        (the pipe shares this engine's registry) by name."""
        return self.metrics.snapshot()

    def stats(self) -> dict:
        out = self.pipe.stats()
        out["microbatch"] = self.microbatch
        out["pack_requests"] = self.pack_requests
        out["mb_injected"] = self._mb_injected.value
        out["rows_injected"] = self._rows_injected.value
        # continuous batching's gate metric: mean fraction of microbatch
        # slots actually filled (1.0 = the pipe runs full)
        out["microbatch_occupancy"] = (
            self._rows_injected.value
            / (self._mb_injected.value * self.microbatch)
            if self._mb_injected.value else None)
        out["stage_blocks"] = [list(ids) for ids in self.stage_block_ids]
        out["planned_link_bytes"] = [p.link_bytes for p in self.plan[:-1]]
        return out

"""Pipeline-parallel ResNet serving engine — persistent per-stage weights,
microbatched requests, the executable Fig 7.

Mirrors ``serving/engine.py``'s submit/step/run surface for the CNN path:
requests carry image batches, the engine splits them into fixed-size
microbatches, and a ``distributed.conv_pipeline.ConvPipeline`` rotates
the microbatches through per-device stages whose (disjoint) constant
weights were placed at construction time.

Stage planning accepts, in precedence order:

* ``plan``        — explicit ``partition.StagePlan`` list (or a
                    ``PartitionResult``, re-balanced to the device count);
* ``stage_blocks``— an explicit stage map: tuple of block-id tuples;
* ``n_stages``    — MAC-balanced contiguous split (partition.plan_stages).

Quantization domains are per-microbatch (the engine's unit of work):
``n_stages=1`` with one microbatch is *bit-identical* to
``resnet.apply`` on the same images, and any stage count is bit-identical
to the per-microbatch reference (``reference_logits``) because stage
boundaries only relocate the int8 edges the single-device compiled
forward already produces (models/resnet.compiled_units).  Microbatches
never span requests — one request's logits must not depend on whoever
shares the queue (per-tensor scales are microbatch-wide).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.compiled_linear import ensure_compiled
from repro.distributed.conv_pipeline import ConvPipeline, PipelineStage
from repro.models import resnet


@dataclasses.dataclass
class PipelineRequest:
    rid: int
    images: np.ndarray                  # (n, H, W, 3) f32
    logits: np.ndarray | None = None
    rows_submitted: int = 0
    rows_done: int = 0
    done: bool = False


def _make_stage_fn(unit_fns):
    def stage_fn(stage_params, carry):
        for fn, p in zip(unit_fns, stage_params):
            carry = fn(p, carry)
        return carry
    return jax.jit(stage_fn)


def reference_logits(params, cfg, x, microbatch: int):
    """The single-device compiled path at the engine's microbatch
    granularity — the bit-identity reference for every stage count.

    Jitted, like the engine's stage programs: slicing the unit list into
    jitted stages is bit-exact vs the whole-model jit (no float op's
    fusion pair spans an int8 edge), whereas op-by-op eager execution
    differs by FMA-contraction ulps from ANY jitted lowering."""
    fn = jax.jit(lambda p, mb: resnet.apply(p, mb, cfg))
    mbs = [x[i:i + microbatch] for i in range(0, x.shape[0], microbatch)]
    return jnp.concatenate([fn(params, mb) for mb in mbs])


class PipelineEngine:
    """Persistent pipeline-parallel serving of the compiled ResNet."""

    def __init__(self, cfg: resnet.ResNetConfig, params, *,
                 mode: str = "int8", sparsity: float = 0.8,
                 n_stages: int | None = None, stage_blocks=None, plan=None,
                 microbatch: int = 2, devices=None, replica: int = 0):
        assert mode != "dense", "the pipeline serves the compiled network"
        self.cfg = cfg
        self.microbatch = microbatch
        # params: the boxed training tree (compiled here, like
        # ServingEngine) or an already-compiled unboxed tree
        self.params = ensure_compiled(params, mode, sparsity)
        units = resnet.compiled_units(self.params, cfg)
        n_blocks = len(units) - 1              # head rides the last stage
        self.plan = self._resolve_plan(plan, stage_blocks, n_stages,
                                       n_blocks, devices)
        self.stage_block_ids = [p.block_ids for p in self.plan]
        devices = self._resolve_devices(devices, len(self.plan))
        self.pipe = ConvPipeline(
            self._build_stages(units, self.stage_block_ids, devices),
            replica=replica)
        self.queue: list[PipelineRequest] = []
        self._rows_in_flight = 0

    # -- stage planning -------------------------------------------------
    def _resolve_plan(self, plan, stage_blocks, n_stages, n_blocks,
                      devices):
        blocks = resnet.conv_blocks_for(self.cfg)
        assert len(blocks) == n_blocks, (len(blocks), n_blocks)
        if isinstance(plan, partition.PartitionResult):
            want = n_stages or (len(devices) if devices else None)
            return plan.stage_plans(blocks, want)
        if plan is not None:                   # explicit StagePlan list
            return list(plan)
        if stage_blocks is not None:           # explicit stage map
            return partition.explicit_stage_plans(blocks, stage_blocks)
        return partition.plan_stages(blocks, n_stages or 1)

    @staticmethod
    def _resolve_devices(devices, n_stages):
        if devices is None:
            from repro.launch.mesh import pipeline_stage_devices
            devices = pipeline_stage_devices(n_stages)
        assert len(devices) >= n_stages, (len(devices), n_stages)
        return list(devices[:n_stages])

    def _build_stages(self, units, stage_block_ids, devices):
        covered = [b for ids in stage_block_ids for b in ids]
        assert covered == list(range(len(units) - 1)), (
            "stage map must cover blocks 0..%d contiguously" % (len(units) - 2),
            stage_block_ids)
        stages = []
        for s, ids in enumerate(stage_block_ids):
            mine = [u for u in units if u.block_id in ids]
            if s == len(stage_block_ids) - 1:
                mine.append(units[-1])         # the head
            # the stage's device holds ONLY these units' constant weights
            stage_params = jax.device_put(
                tuple(u.params for u in mine), devices[s])
            stages.append(PipelineStage(
                index=s, device=devices[s],
                fn=_make_stage_fn(tuple(u.fn for u in mine)),
                params=stage_params,
                unit_names=tuple(u.name for u in mine)))
        return stages

    # -- request management --------------------------------------------
    def submit(self, req: PipelineRequest):
        req.logits = None
        req.rows_submitted = req.rows_done = 0
        req.done = False
        self.queue.append(req)

    def _next_microbatch(self):
        """Head-of-queue rows, at most ``microbatch`` of them, never
        crossing a request boundary (per-microbatch quantization)."""
        while self.queue:
            req = self.queue[0]
            if len(req.images) == 0:           # zero-row request: complete
                req.logits = np.zeros((0, self.cfg.num_classes), np.float32)
                req.done = True
                self.queue.pop(0)
                continue
            start = req.rows_submitted
            if start >= len(req.images):
                self.queue.pop(0)
                continue
            stop = min(start + self.microbatch, len(req.images))
            req.rows_submitted = stop
            if stop >= len(req.images):
                self.queue.pop(0)
            return (req, start), jnp.asarray(req.images[start:stop],
                                             jnp.float32)
        return None, None

    def step(self) -> bool:
        """Inject one microbatch (if any is queued) and advance the
        schedule one tick; completed microbatches land in their request's
        logits.  Returns False once idle."""
        tag = mb = None
        if self.pipe.inlet_free:
            tag, mb = self._next_microbatch()
        if mb is None and not self.pipe.busy:
            return False
        if mb is not None:
            self._rows_in_flight += int(mb.shape[0])
        for (req, start), out in self.pipe.tick(inject=mb, tag=tag):
            out = np.asarray(out)
            if req.logits is None:
                req.logits = np.zeros((len(req.images), out.shape[-1]),
                                      out.dtype)
            req.logits[start:start + out.shape[0]] = out
            req.rows_done += out.shape[0]
            req.done = req.rows_done >= len(req.images)
            self._rows_in_flight -= out.shape[0]
        return True

    def run(self, requests: list) -> list:
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return requests

    @property
    def pending_rows(self) -> int:
        """Rows accepted but not yet delivered: the queue's unsubmitted
        rows plus the exact rows still rotating through the stages
        (partial microbatches count their real size) — the load metric
        ``serving.frontend.ResNetFrontend``'s least-loaded router
        compares across replicas."""
        queued = sum(len(r.images) - r.rows_submitted for r in self.queue)
        return queued + self._rows_in_flight

    def run_batch(self, x) -> jnp.ndarray:
        """Convenience: one anonymous request, returns stacked logits."""
        req = PipelineRequest(rid=-1, images=np.asarray(x))
        self.run([req])
        return jnp.asarray(req.logits)

    def stats(self) -> dict:
        out = self.pipe.stats()
        out["microbatch"] = self.microbatch
        out["stage_blocks"] = [list(ids) for ids in self.stage_block_ids]
        out["planned_link_bytes"] = [p.link_bytes for p in self.plan[:-1]]
        return out

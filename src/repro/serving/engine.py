"""Batched serving engine: persistent compiled weights + continuous
batching over fixed decode slots.

The paper's deployment model is a *persistent* network (weights compiled
into the fabric, requests streamed through).  The TPU analogue: weights
packed by core.compiled_linear live on device for the process lifetime;
requests are slotted into a fixed decode batch; prefill fills a slot's
cache, decode advances all slots together; finished slots are refilled
(continuous batching).  Slot count == the compiled decode batch, so no
recompilation ever happens at serve time.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import ArchConfig
from repro.core.compiled_linear import compile_params
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 32
    eos_id: int | None = None
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, mode: str = "int8",
                 sparsity: float = 0.8, batch_slots: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.mode = mode
        self.slots = batch_slots
        self.max_seq = max_seq
        packed = compile_params(params, mode=mode, sparsity=sparsity) \
            if mode != "dense" else params
        self.params = nn.unbox(packed)
        self.cache = nn.unbox(lm.cache_init(cfg, batch_slots, max_seq))
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * batch_slots
        self._decode = jax.jit(
            lambda p, c, b: lm.forward_decode(p, b, cfg, c))
        self._prefill_cache = {}

    # -- request management --------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request):
        """Prefill a single request into batch slot ``slot``.

        Single-slot prefill uses a batch-1 cache then copies it into the
        shared decode cache at the slot index (the production engine
        would prefill on a separate prefill mesh; same dataflow)."""
        L = len(req.prompt)
        key = L
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, c, b: lm.forward_prefill(p, b, self.cfg, c))
        cache1 = nn.unbox(lm.cache_init(self.cfg, 1, self.max_seq))
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        logits, cache1 = self._prefill_cache[key](self.params, cache1,
                                                  {"tokens": toks})
        nxt = int(jnp.argmax(logits[0, -1]))
        req.tokens_out.append(nxt)
        self.cache = _merge_slot_cache(self.cache, cache1, slot)

    def step(self):
        """Admit queued requests into free slots, then one decode step."""
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_one(slot, req)
                self.active[slot] = req
        if not any(self.active):
            return False
        last = np.zeros((self.slots, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is not None and req.tokens_out:
                last[slot, 0] = req.tokens_out[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"token": jnp.asarray(last)})
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.tokens_out.append(tok)
            if (len(req.tokens_out) >= req.max_new_tokens or
                    (req.eos_id is not None and tok == req.eos_id)):
                req.done = True
                self.active[slot] = None
        return True

    def run(self, requests):
        for r in requests:
            self.submit(r)
        while self.queue or any(self.active):
            self.step()
        return requests


def _merge_slot_cache(batch_cache, one_cache, slot: int):
    """Copy a batch-1 cache pytree into slot ``slot`` of the batch cache.

    Batch-leading leaves (dim0 == slots) get the row written; scalar
    'length'/'pos' leaves take the max (slots prefilled to equal length
    in the engine; per-slot lengths live in 'pos')."""
    def merge(full, one):
        if one.ndim == 0:
            return jnp.maximum(full, one)
        if full.shape[0] != one.shape[0]:  # batch-leading leaf
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype),
                (slot,) + (0,) * (one.ndim - 1))
        # stacked-layer leaf: recurse one dim in
        return jax.vmap(lambda f, o: _merge_row(f, o, slot))(full, one)

    return jax.tree.map(merge, batch_cache, one_cache)


def _merge_row(full, one, slot):
    if one.ndim == 0:
        return jnp.maximum(full, one)
    return jax.lax.dynamic_update_slice(
        full, one.astype(full.dtype), (slot,) + (0,) * (one.ndim - 1))

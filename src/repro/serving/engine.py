"""Batched serving engine: persistent compiled weights + continuous
batching over fixed decode slots.

The paper's deployment model is a *persistent* network (weights compiled
into the fabric, requests streamed through).  The TPU analogue: weights
packed by core.compiled_linear live on device for the process lifetime;
requests are slotted into a fixed decode batch; prefill fills a slot's
cache, decode advances all slots together; finished slots are refilled
(continuous batching).  Slot count == the compiled decode batch, so no
recompilation ever happens at serve time.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import ArchConfig
from repro.core.compiled_linear import compile_params
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 32
    eos_id: int | None = None
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket_len(L: int, max_seq: int) -> int:
    """Prompt-length bucket: the next power of two (>= 8), capped at the
    engine's max_seq.  One compiled prefill program serves a whole bucket
    — end-padding is exact under causal attention (lm.forward_prefill)."""
    b = 8
    while b < L:
        b <<= 1
    return min(b, max_seq)


class ServingEngine:
    # jitted prefill programs kept per LENGTH BUCKET, LRU-bounded: the old
    # per-exact-length cache compiled one program per distinct prompt
    # length, unbounded.  Power-of-two bucketing alone bounds the count to
    # ~log2(max_seq); the LRU cap is a hard backstop.
    PREFILL_CACHE_MAX = 8

    def __init__(self, cfg: ArchConfig, params, *, mode: str = "int8",
                 sparsity: float = 0.8, batch_slots: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.mode = mode
        self.slots = batch_slots
        self.max_seq = max_seq
        packed = compile_params(params, mode=mode, sparsity=sparsity) \
            if mode != "dense" else params
        self.params = nn.unbox(packed)
        self.cache = nn.unbox(lm.cache_init(cfg, batch_slots, max_seq))
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * batch_slots
        self._decode = jax.jit(
            lambda p, c, b: lm.forward_decode(p, b, cfg, c))
        self._prefill_cache: OrderedDict = OrderedDict()
        # bucketed (end-padded) prefill is exact only when every mixer is
        # causal attention: pad tokens advance mamba/rwkv recurrent scan
        # states, which no length rewind can undo.  Recurrent stacks keep
        # exact-length programs (the LRU bound below still applies).
        self._bucket_prefill = (not cfg.encoder_decoder and
                                all(sig["kind"] == "attn"
                                    for sig in cfg.layer_sigs()))

    # -- request management --------------------------------------------
    def submit(self, req: Request):
        if len(req.prompt) > self.max_seq:
            # _prefill_one writes all L prompt tokens into a (1, bucket)
            # buffer whose bucket is capped at max_seq — reject at the
            # front door instead of shape-erroring deep in numpy
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds the engine's "
                f"max_seq={self.max_seq}; truncate the prompt or build "
                f"the engine with a larger max_seq")
        if len(req.prompt) + req.max_new_tokens - 1 > self.max_seq:
            # decode token i lands at cache position L + i - 2: past
            # max_seq, dynamic_update_slice CLAMPS the index and silently
            # corrupts the last cache slot — reject the budget up front
            # (an early EOS could have fit, but silent corruption on the
            # no-EOS path is the worse failure)
            raise ValueError(
                f"prompt length {len(req.prompt)} + max_new_tokens "
                f"{req.max_new_tokens} - 1 exceeds max_seq="
                f"{self.max_seq}; the decode budget would overrun the "
                f"cache — shorten one or raise max_seq")
        self.queue.append(req)

    @staticmethod
    def _check_done(req: Request) -> bool:
        """Done-conditions shared by prefill and decode: token budget
        spent, or the latest token is EOS."""
        if (len(req.tokens_out) >= req.max_new_tokens or
                (req.eos_id is not None and req.tokens_out and
                 req.tokens_out[-1] == req.eos_id)):
            req.done = True
        return req.done

    def _prefill_fn(self, bucket: int):
        """The compiled prefill program for a length bucket (LRU)."""
        if bucket in self._prefill_cache:
            self._prefill_cache.move_to_end(bucket)
        else:
            while len(self._prefill_cache) >= self.PREFILL_CACHE_MAX:
                self._prefill_cache.popitem(last=False)
            self._prefill_cache[bucket] = jax.jit(
                lambda p, c, b: lm.forward_prefill(p, b, self.cfg, c))
        return self._prefill_cache[bucket]

    def _prefill_one(self, slot: int, req: Request):
        """Prefill a single request into batch slot ``slot``.

        Single-slot prefill uses a batch-1 cache then copies it into the
        shared decode cache at the slot index (the production engine
        would prefill on a separate prefill mesh; same dataflow).  For
        attention-only stacks the prompt is end-padded to its power-of-
        two bucket and the true length rides the batch — exact, see
        lm.forward_prefill; recurrent stacks prefill at exact length."""
        L = len(req.prompt)
        bucket = _bucket_len(L, self.max_seq) if self._bucket_prefill else L
        fn = self._prefill_fn(bucket)
        cache1 = nn.unbox(lm.cache_init(self.cfg, 1, self.max_seq))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :L] = np.asarray(req.prompt, np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if bucket != L:
            batch["length"] = jnp.asarray([L], jnp.int32)
        logits, cache1 = fn(self.params, cache1, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.tokens_out.append(nxt)
        self.cache = _merge_slot_cache(self.cache, cache1, slot)

    def step(self):
        """Admit queued requests into free slots, then one decode step.

        Done-conditions are checked right after prefill (which already
        produced one token): a ``max_new_tokens=1`` request or a
        prefill-produced EOS completes immediately and frees the slot
        for the next queued request *before* any decode step — the old
        path unconditionally decoded once more, overshooting the token
        budget and ignoring a prefill EOS."""
        for slot in range(self.slots):
            while self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_one(slot, req)
                if not self._check_done(req):
                    self.active[slot] = req
        if not any(self.active):
            return False
        last = np.zeros((self.slots, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is not None and req.tokens_out:
                last[slot, 0] = req.tokens_out[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"token": jnp.asarray(last)})
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.tokens_out.append(int(nxt[slot]))
            if self._check_done(req):
                self.active[slot] = None
        return True

    def run(self, requests):
        for r in requests:
            self.submit(r)
        while self.queue or any(self.active):
            self.step()
        return requests


def _merge_slot_cache(batch_cache, one_cache, slot: int):
    """Copy a batch-1 cache pytree into slot ``slot`` of the batch cache.

    Batch-leading leaves (dim0 == slots) get the row written; scalar
    'length'/'pos' leaves take the max (slots prefilled to equal length
    in the engine; per-slot lengths live in 'pos')."""
    def merge(full, one):
        if one.ndim == 0:
            return jnp.maximum(full, one)
        if full.shape[0] != one.shape[0]:  # batch-leading leaf
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype),
                (slot,) + (0,) * (one.ndim - 1))
        # stacked-layer leaf: recurse one dim in
        return jax.vmap(lambda f, o: _merge_row(f, o, slot))(full, one)

    return jax.tree.map(merge, batch_cache, one_cache)


def _merge_row(full, one, slot):
    if one.ndim == 0:
        return jnp.maximum(full, one)
    return jax.lax.dynamic_update_slice(
        full, one.astype(full.dtype), (slot,) + (0,) * (one.ndim - 1))

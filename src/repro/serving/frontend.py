"""Replicated-pipeline serving front-end — many Fig 7 chains behind one
front door.

The paper's deployment story does not stop at one multi-chip pipeline:
"heavy traffic from millions of users" means N *data-parallel replicas*
of the layer-pipelined network running over disjoint device groups, the
same scale-out move HPIPE makes across independent device clusters.  At
that point the Memory-Efficient Dataflow literature's lesson applies:
the front door — admission and batching — becomes the bottleneck before
the kernels do, so it gets its own component.

``ResNetFrontend`` owns the shared request queue and N
``serving.pipeline.PipelineEngine`` replicas:

* **Replica carving** — ``launch.mesh.replica_pipeline_devices`` splits
  the local device list into disjoint contiguous groups, one stage chain
  per replica; every replica holds the FULL network (split over its own
  stages), and all replicas share ONE host-side compiled param tree —
  compile once, ``device_put`` per stage (spy-tested in
  tests/test_frontend.py).
* **Admission + routing** — requests wait in the front-door queue until
  the least-loaded healthy replica (by ``PipelineEngine.pending_rows``)
  has room under ``admit_rows``.  Dispatch is ROW granular by default
  (``continuous=True``): two small requests can share a microbatch on
  one replica, a large request never head-of-line-blocks the door.
  ``continuous=False`` restores whole-request dispatch.
* **SLO-aware admission** — with ``slo_p95_s`` set, ``submit`` sheds
  instead of queueing forever: the estimated wait (door backlog + fleet
  in-flight rows, times the EWMA per-row service time measured from
  completions) is compared against the p95 budget, and a request that
  cannot make it gets a typed ``Rejected`` outcome at the door — load
  the fleet cannot carry is refused where the client can see it, not
  buried in an unbounded queue.  ``admit_rows`` stays the inner,
  per-replica backpressure.
* **Failure detection + recovery** (DESIGN.md §10) — a replica that
  raises ``ReplicaFailure`` mid-step (fail-stop) is marked failed on the
  spot; one whose ``progress_marker`` freezes for ``watchdog_ticks``
  steps while it claims work (wedged or degraded-past-usefulness) is
  failed by the watchdog.  Either way its unfinished rows are extracted
  (``PipelineEngine.extract_pending``) and requeued to healthy replicas;
  per-row quantization domains (§9) make the re-executed rows
  bit-identical to the never-failed reference.  ``restart_replica``
  re-admits a failed replica with a fresh engine (fresh ``device_put``
  of its stage subtrees, same shared host tree).
* **Quantization-domain safety** — quantization domains are PER ROW
  (DESIGN.md §9): one image's logits depend only on its own pixels, so
  any packing — across requests inside a replica's microbatch, one
  request's rows split across replicas, or a requeue after failure — is
  bit-identical to ``serving.pipeline.reference_logits`` no matter the
  replica count, arrival order, interleaving, or fault schedule.
* **Front-door validation** — ``submit`` rejects malformed requests with
  a clear ``ValueError`` (mirroring ``ServingEngine.submit``'s
  hardening) instead of shape-erroring deep inside a packed microbatch:
  images must be float-castable, rank-4, matching the compiled graph's
  entry-node geometry ``(n, H, W, C)``, and finite.  It also rejects re-submission of
  a request object that is still queued or in flight, and a duplicate
  ``rid`` among live requests — both used to silently reset the victim's
  dispatch accounting mid-flight.
* **Accounting** — queue depth (current + max), per-replica bubble and
  rows dispatched, failure/requeue/shed counters, the service-rate
  estimate, and wall-clock request latency (submit -> done) reported as
  p50/p95 over a bounded sliding window of the most recent
  ``latency_window`` completions (an open-loop serve runs indefinitely;
  an append-forever list would leak).

Surface mirrors the existing engines: ``submit`` / ``step`` / ``run`` /
``stats`` (plus ``run_batch`` for one anonymous request).  ``run`` takes
a ``max_steps`` last-resort guard: if the fleet cannot drain (e.g. a
wedge with the watchdog disabled), it raises a diagnosable
``TimeoutError`` with the fleet stats attached instead of spinning
forever.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.compiled_linear import ensure_compiled
from repro.launch.mesh import replica_pipeline_devices
from repro.obs.metrics import LIFE, MetricsRegistry, percentile
from repro.serving.faults import ReplicaFailure
from repro.serving.pipeline import PipelineEngine, PipelineRequest


@dataclasses.dataclass
class FrontendRequest(PipelineRequest):
    """A ``PipelineRequest`` plus the front-end's lifecycle accounting."""
    replica: int | None = None          # first replica assigned at dispatch
    rows_routed: int = 0                # dispatch cursor (continuous mode)
    rejected: bool = False              # shed by SLO-aware admission
    t_submit: float | None = None
    t_admitted: float | None = None     # admission decision made
    t_first_dispatch: float | None = None
    t_last_dispatch: float | None = None
    t_done: float | None = None

    @property
    def latency_s(self) -> float | None:
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class Admitted:
    """``submit`` outcome: the request is queued (or, zero-row, already
    complete).  ``estimated_wait_s`` is None until the fleet has measured
    a service rate."""
    rid: int
    rows: int
    estimated_wait_s: float | None


@dataclasses.dataclass(frozen=True)
class Rejected:
    """``submit`` outcome: the request was SHED at the door — its
    estimated wait exceeds the p95 latency budget, so queueing it would
    only break the SLO for it *and* everyone behind it.  The client sees
    a typed outcome (retry later / elsewhere) instead of a silent,
    unbounded queue."""
    rid: int
    rows: int
    estimated_wait_s: float
    slo_p95_s: float
    reason: str = "p95-budget"


def _percentile(xs, q: float) -> float | None:
    """Kept as the frontend's percentile spelling; one implementation
    (``obs.metrics.percentile``) serves the whole stack."""
    return percentile(xs, q)


class ResNetFrontend:
    """Admission queue + least-loaded routing over N pipeline replicas,
    with failure recovery and SLO-aware shedding.

    Despite the historical name, the front door serves any model exposing
    the zoo protocol (``cfg.graph()``/``cfg.apply()``, DESIGN.md §12) —
    the expected input geometry is derived from the compiled graph's
    entry node, not hardcoded."""

    def __init__(self, cfg, params, *,
                 mode: str = "int8", sparsity: float = 0.8,
                 n_replicas: int = 2, n_stages: int = 1,
                 stage_blocks=None, plan=None, microbatch: int = 2,
                 devices=None, admit_rows: int | None = None,
                 continuous: bool = True,
                 watchdog_ticks: int | None = 8, recover: bool = True,
                 slo_p95_s: float | None = None,
                 latency_window: int = 2048,
                 clock=time.perf_counter, telemetry=None):
        assert n_replicas >= 1, n_replicas
        self.cfg = cfg
        self._in_shape = cfg.graph().in_shape()
        self.microbatch = microbatch
        self.continuous = continuous
        self.telemetry = telemetry
        if telemetry is not None and telemetry.trace is not None:
            # spans and SLO arithmetic must share one time axis: the
            # trace's clock wins (Telemetry docstring) — callers with a
            # fake clock pass it to Telemetry too
            clock = telemetry.clock
            telemetry.trace.name_process(0, "frontend")
        # compile ONCE; every replica shares this host-side tree and only
        # device_puts its own stages' subtrees onto its device group
        self.params = ensure_compiled(params, mode, sparsity)
        self._groups = replica_pipeline_devices(n_replicas, n_stages,
                                                devices=devices)
        # kept so restart_replica can rebuild an engine identically
        # (fresh device_put onto the same group, same shared host tree;
        # telemetry rides along so a restarted replica keeps tracing)
        self._replica_kwargs = dict(
            mode=mode, sparsity=sparsity, n_stages=n_stages,
            stage_blocks=stage_blocks, plan=plan, microbatch=microbatch,
            pack_requests=continuous, telemetry=telemetry)
        self.replicas = [
            PipelineEngine(cfg, self.params, devices=self._groups[r],
                           replica=r, **self._replica_kwargs)
            for r in range(n_replicas)]
        # front door: a replica chain absorbs n_stages in-flight
        # microbatches; double that before the queue holds requests back
        self.admit_rows = (2 * n_stages * microbatch
                           if admit_rows is None else admit_rows)
        assert self.admit_rows >= 1, (
            "admit_rows must be >= 1 — 0 would deadlock the front door "
            "(an idle replica could never be handed work)", admit_rows)
        assert watchdog_ticks is None or watchdog_ticks >= 1, watchdog_ticks
        assert latency_window >= 1, latency_window
        self.watchdog_ticks = watchdog_ticks
        self.recover = recover
        self.slo_p95_s = slo_p95_s
        self.latency_window = latency_window
        self._clock = clock
        self.queue: deque = deque()
        self._requeue: deque = deque()         # (req, start, stop) spans
        self._inflight: list = []
        self._live: dict = {}                  # rid -> live request
        self._door_rows = 0                    # rows waiting at the door
        # every wave/lifetime statistic lives in the registry: the
        # wave/life scope split IS the reset_stats contract, testable
        # structurally (registry.wave_names()); direct references keep
        # the hot path at one attribute add per event, and the old
        # attribute names survive as read-only property views below
        self.metrics = m = MetricsRegistry()
        self._rows_dispatched_c = [
            m.counter(f"door.replica{r}.rows_dispatched")
            for r in range(n_replicas)]
        self._requests_dispatched_c = [
            m.counter(f"door.replica{r}.requests_dispatched")
            for r in range(n_replicas)]
        self._max_queue_depth = m.highwater("door.max_queue_depth")
        # bounded reservoir: p50/p95 over the most recent latency_window
        # completions — an open-loop serve must not grow without bound
        self._latencies = m.reservoir("door.latency_s", latency_window)
        self._requests_done = m.counter("door.requests_done")
        # failure / shed accounting
        self.failed = [False] * n_replicas
        self.failures: list = []               # {replica, reason, step}
        self._replicas_failed = m.counter("door.replicas_failed")
        self._requeues = m.counter("door.requeues")   # spans requeued
        self._rows_requeued = m.counter("door.rows_requeued")
        self._rejected = m.counter("door.rejected_requests")
        self._rejected_rows = m.counter("door.rejected_rows")
        self._steps_c = m.counter("door.steps")
        self._marker = [None] * n_replicas     # watchdog progress markers
        self._stall = [0] * n_replicas
        # EWMA per-row service time, measured fleet-wide from completions
        # (calibration, not a wave stat: LIFE scope survives reset_wave)
        self._row_time_g = m.gauge("door.row_time_s", scope=LIFE,
                                   initial=None)
        self._rows_seen_g = m.gauge("door.rows_seen", scope=LIFE,
                                    initial=0)

    # -- registry views (the pre-registry attribute surface) -----------
    @property
    def rows_dispatched(self) -> list:
        return [c.value for c in self._rows_dispatched_c]

    @property
    def requests_dispatched(self) -> list:
        return [c.value for c in self._requests_dispatched_c]

    @property
    def max_queue_depth(self) -> int:
        return int(self._max_queue_depth.value)

    @property
    def requests_done(self) -> int:
        return self._requests_done.value

    @property
    def replicas_failed(self) -> int:
        return self._replicas_failed.value

    @property
    def requeues(self) -> int:
        return self._requeues.value

    @property
    def rows_requeued(self) -> int:
        return self._rows_requeued.value

    @property
    def rejected_count(self) -> int:
        return self._rejected.value

    @property
    def rejected_rows(self) -> int:
        return self._rejected_rows.value

    @property
    def _steps(self) -> int:
        return self._steps_c.value

    @property
    def _row_time(self):
        return self._row_time_g.value

    @_row_time.setter
    def _row_time(self, v):                    # tests seed calibration
        self._row_time_g.set(v)

    @property
    def _rows_seen(self):
        return self._rows_seen_g.value

    @_rows_seen.setter
    def _rows_seen(self, v):
        self._rows_seen_g.set(v)

    # -- request management --------------------------------------------
    def _validate(self, req) -> np.ndarray:
        """Front-door request hardening: reject malformed image payloads
        with a clear ValueError instead of shape-erroring deep inside a
        packed microbatch (where the failure would also take DOWN the
        innocent requests sharing that microbatch)."""
        try:
            images = np.asarray(req.images, dtype=np.float32)
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"request {req.rid}: images must be castable to float32 "
                f"(got {type(req.images).__name__}: {e})") from None
        # expected geometry comes from the compiled graph's entry node,
        # not a hardcoded 224x224x3: the fleet serves whatever model the
        # config's graph describes (regression: tests/test_graph.py)
        want = self._in_shape
        if images.ndim != 4 or images.shape[1:] != want:
            raise ValueError(
                f"request {req.rid}: images must have shape "
                f"(n, {want[0]}, {want[1]}, {want[2]}) — rows from "
                f"different requests are packed into one microbatch, so "
                f"every request must match the model's input geometry "
                f"exactly; got {images.shape}")
        if images.size and not np.isfinite(images).all():
            raise ValueError(
                f"request {req.rid}: images contain NaN/Inf pixels — a "
                f"non-finite row would corrupt its per-row quantization "
                f"scale and produce garbage logits; sanitize upstream")
        return images

    def _check_not_live(self, req):
        """Re-submitting a live request object used to silently reset its
        ``rows_routed``/``done`` mid-flight, corrupting dispatch
        accounting for rows a replica was already executing; a second
        request reusing a live ``rid`` would corrupt the live registry
        the same way.  Both are caller bugs — reject loudly."""
        for live in self._live.values():
            if live is req:
                raise ValueError(
                    f"request {req.rid} is already queued or in flight — "
                    f"re-submitting would reset its dispatch accounting "
                    f"mid-flight; wait for done (or submit a new request "
                    f"object)")
        if req.rid in self._live:
            raise ValueError(
                f"request rid={req.rid} duplicates a live request's rid — "
                f"rids must be unique among queued/in-flight requests")

    def _estimate_wait_s(self, extra_rows: int) -> float | None:
        """Queue-theory estimate of a new request's completion wait:
        (door backlog + healthy replicas' pending rows + its own rows)
        x the measured per-row service time.  None until the fleet has
        completed enough rows to measure a rate (then admission cannot
        shed — it has no evidence yet)."""
        if self._row_time is None:
            return None
        healthy = [self.replicas[r] for r in self._healthy()]
        if not healthy:
            return None
        backlog = self._door_rows + sum(e.pending_rows for e in healthy)
        return (backlog + extra_rows) * self._row_time

    def submit(self, req):
        """Validate and admit a request into the front-door queue
        (routing happens at ``step`` time, when replica load is
        current).  Raises ValueError on malformed images, re-submission
        of a live request, or a duplicate live rid.  Returns a typed
        outcome: ``Admitted``, or — when ``slo_p95_s`` is set and the
        estimated wait exceeds it — ``Rejected`` (the request is NOT
        queued; ``req.rejected`` is set)."""
        images = self._validate(req)
        self._check_not_live(req)
        req.images = images
        req.logits = None
        req.done = False
        req.rejected = False
        req.replica = None
        req.rows_submitted = req.rows_done = req.rows_routed = 0
        req.t_submit = self._clock()
        req.t_admitted = req.t_first_dispatch = req.t_last_dispatch = None
        req.t_done = None
        tr = (self.telemetry.trace if self.telemetry is not None else None)
        n_rows = len(req.images)
        est = self._estimate_wait_s(n_rows)
        if (self.slo_p95_s is not None and est is not None and n_rows
                and est > self.slo_p95_s):
            req.rejected = True
            self._rejected.inc()
            self._rejected_rows.inc(n_rows)
            if tr is not None:
                tr.instant("shed", "door", 0, req.rid, rid=req.rid,
                           rows=n_rows, estimated_wait_s=est,
                           slo_p95_s=self.slo_p95_s)
            return Rejected(rid=req.rid, rows=n_rows, estimated_wait_s=est,
                            slo_p95_s=self.slo_p95_s)
        self._live[req.rid] = req
        req.t_admitted = self._clock()
        if n_rows == 0:
            # zero-row request: complete at the front door — it owns no
            # microbatch slot, so don't make a replica tick for it; its
            # queue/dispatch spans collapse to zero duration
            req.t_first_dispatch = req.t_last_dispatch = req.t_admitted
            req.logits = np.zeros((0, self.cfg.num_classes), np.float32)
            req.done = True
            self._inflight.append(req)      # _collect stamps t_done
            return Admitted(rid=req.rid, rows=0, estimated_wait_s=est)
        self.queue.append(req)
        self._door_rows += n_rows
        self._max_queue_depth.observe(len(self.queue))
        return Admitted(rid=req.rid, rows=n_rows, estimated_wait_s=est)

    # -- routing ---------------------------------------------------------
    def _healthy(self) -> list:
        return [r for r in range(len(self.replicas)) if not self.failed[r]]

    def _best_replica(self):
        """(replica index, spare rows) of the least-loaded healthy
        replica, or (None, 0) when every replica is failed."""
        healthy = self._healthy()
        if not healthy:
            return None, 0
        loads = [(self.replicas[r].pending_rows, r) for r in healthy]
        load, r = min(loads)
        return r, self.admit_rows - load

    def _dispatch(self):
        """Route rows to the least-loaded healthy replica while it has
        room under ``admit_rows`` — requeued failure spans first (they
        are the oldest work in the system), then the FIFO queue.
        Continuous mode hands off ROWS (the replica packs them into
        shared microbatches); whole-request mode keeps fresh requests
        intact (requeued spans are row-granular by nature).  Each
        hand-off reads ``pending_rows`` — O(1), incrementally maintained
        by the engine — so dispatching R requests costs
        O(R · n_replicas), not the O(R²) a per-hand-off queue scan used
        to cost under load."""
        while self._requeue or self.queue:
            r, room = self._best_replica()
            if r is None or room <= 0:
                return                      # backpressure: hold the door
            now = self._clock()
            if self._requeue:
                req, start, stop = self._requeue[0]
                take = min(room, stop - start)
                self.replicas[r].submit_rows(req, start, start + take)
                self._rows_dispatched_c[r].inc(take)
                self._door_rows -= take
                if getattr(req, "t_first_dispatch", 0) is None:
                    req.t_first_dispatch = now
                req.t_last_dispatch = now
                if start + take >= stop:
                    self._requeue.popleft()
                else:
                    self._requeue[0] = (req, start + take, stop)
                continue
            req = self.queue[0]
            if self.continuous:
                take = min(room, len(req.images) - req.rows_routed)
                if req.rows_routed == 0:    # first rows of this request
                    req.replica = r
                    req.t_first_dispatch = now
                    self._requests_dispatched_c[r].inc()
                    self._inflight.append(req)
                self.replicas[r].submit_rows(
                    req, req.rows_routed, req.rows_routed + take)
                req.rows_routed += take
                req.t_last_dispatch = now
                self._rows_dispatched_c[r].inc(take)
                self._door_rows -= take
                if req.rows_routed >= len(req.images):
                    self.queue.popleft()
            else:
                self.queue.popleft()
                req.replica = r
                self.replicas[r].submit(req)
                req.rows_routed = len(req.images)
                req.t_first_dispatch = req.t_last_dispatch = now
                self._rows_dispatched_c[r].inc(len(req.images))
                self._door_rows -= len(req.images)
                self._requests_dispatched_c[r].inc()
                self._inflight.append(req)

    def _scan_door_rows(self) -> int:
        """Linear-scan oracle for ``_door_rows`` (tests only)."""
        return (sum(len(r.images) - r.rows_routed for r in self.queue)
                + sum(stop - start for _, start, stop in self._requeue))

    # -- failure detection + recovery -----------------------------------
    def _fail_replica(self, r: int, reason: str):
        """Mark replica ``r`` failed, drain its bookkeeping, and (with
        ``recover``) requeue every row it still owed — per-row
        quantization domains make the re-execution bit-identical to the
        never-failed reference, so recovery is invisible in the logits
        (DESIGN.md §10)."""
        self.failed[r] = True
        self._replicas_failed.inc()
        self.failures.append({"replica": r, "reason": reason,
                              "step": self._steps})
        if (self.telemetry is not None
                and self.telemetry.trace is not None):
            self.telemetry.trace.instant("replica-failed", "door", 0, 0,
                                         replica=r, reason=reason)
        if not self.recover:
            return
        spans = self.replicas[r].extract_pending()
        for req, start, stop in spans:
            self._requeue.append((req, start, stop))
            self._rows_requeued.inc(stop - start)
            self._door_rows += stop - start
        self._requeues.inc(len(spans))

    def _watch(self, r: int, eng):
        """Per-replica progress watchdog: an engine whose
        ``progress_marker`` freezes for ``watchdog_ticks`` consecutive
        steps while it has work is wedged (hung device, or degraded past
        usefulness) — mark it failed and requeue.  A healthy busy
        replica changes its marker on EVERY step (the inlet occupancy
        pattern shifts even when row counts hold), so the threshold
        costs no false positives."""
        marker = eng.progress_marker
        has_work = eng.pending_rows > 0 or eng.pipe.busy
        if has_work and marker == self._marker[r]:
            self._stall[r] += 1
            if self._stall[r] >= self.watchdog_ticks:
                self._fail_replica(
                    r, f"watchdog: no progress in {self._stall[r]} steps "
                       f"with {eng.pending_rows} rows pending")
        else:
            self._stall[r] = 0
        self._marker[r] = marker

    def restart_replica(self, r: int):
        """Re-admit replica ``r`` with a brand-new engine: fresh
        ``device_put`` of its stage subtrees onto the same device group,
        aliasing the same shared host-side compiled tree.  Restarting a
        live replica first drains and requeues whatever it holds (a
        failed one was already drained), so no rows are lost either way.
        Returns the new engine."""
        for req, start, stop in self.replicas[r].extract_pending():
            self._requeue.append((req, start, stop))
            self._rows_requeued.inc(stop - start)
            self._door_rows += stop - start
        self.replicas[r] = PipelineEngine(
            self.cfg, self.params, devices=self._groups[r], replica=r,
            **self._replica_kwargs)
        self.failed[r] = False
        self._marker[r] = None
        self._stall[r] = 0
        return self.replicas[r]

    # -- the drive loop --------------------------------------------------
    def _measure_service_rate(self, t_step_start: float):
        """EWMA the fleet's per-row service time from the rows that
        completed this step, over this step's own duration (idle steps
        contribute nothing, so open-loop arrival gaps never pollute the
        estimate): the admission controller's denominator.  Survives
        ``reset_stats`` — it is calibration, not a wave statistic — and
        tolerates engine restarts (the odometer total can only step
        backwards then, which is skipped)."""
        total = sum(eng.rows_completed for eng in self.replicas)
        delta = total - self._rows_seen
        self._rows_seen = total
        if delta > 0:
            dt = self._clock() - t_step_start
            if dt > 0:
                sample = dt / delta
                self._row_time = (sample if self._row_time is None else
                                  0.7 * self._row_time + 0.3 * sample)

    def reset_service_rate(self):
        """Forget the measured per-row service time.  The EWMA's first
        samples absorb whatever the first wave cost — including jit
        compilation, which can be 1000x the steady-state rate — so
        benches and drivers call this after their warmup wave to let the
        admission controller calibrate on steady-state completions
        only."""
        self._row_time = None

    def _trace_request(self, tr, req):
        """Emit the request's lifecycle as four contiguous spans on its
        own pid-0 track (tid = rid): admission → queue → dispatch →
        collect; the stage-tick spans it rode live on the replica pids.
        Missing stamps (zero-row requests own no dispatch) collapse the
        corresponding span to zero duration, keeping the chain complete
        for every completed request."""
        a = req.t_admitted if req.t_admitted is not None else req.t_submit
        fd = (req.t_first_dispatch if req.t_first_dispatch is not None
              else a)
        ld = (req.t_last_dispatch if req.t_last_dispatch is not None
              else fd)
        rid, rows = req.rid, len(req.images)
        tr.name_thread(0, rid, f"req {rid}")
        tr.span("admission", "request", 0, rid, req.t_submit, a,
                rid=rid, rows=rows)
        tr.span("queue", "request", 0, rid, a, fd, rid=rid, rows=rows)
        tr.span("dispatch", "request", 0, rid, fd, ld, rid=rid, rows=rows,
                replica=req.replica)
        tr.span("collect", "request", 0, rid, ld, req.t_done,
                rid=rid, rows=rows)

    def _collect(self):
        done, still = [], []
        for req in self._inflight:
            (done if req.done else still).append(req)
        now = self._clock()
        tr = (self.telemetry.trace if self.telemetry is not None else None)
        for req in done:
            req.t_done = now
            self._latencies.append(req.t_done - req.t_submit)
            self._live.pop(req.rid, None)
            if tr is not None:
                self._trace_request(tr, req)
        self._inflight = still                 # one linear pass per step
        self._requests_done.inc(len(done))
        return done

    def step(self) -> bool:
        """Dispatch what the healthy replicas can absorb, advance each
        one tick (catching fail-stops, running the watchdog), and harvest
        completed requests.  Returns False once the whole fleet is idle.
        Raises RuntimeError when work is pending but every replica has
        failed — a dead fleet is diagnosable, not an infinite loop."""
        self._steps_c.inc()
        t_start = self._clock()
        if not self._healthy() and (self.queue or self._requeue
                                    or self._inflight):
            err = RuntimeError(
                f"all {len(self.replicas)} replicas failed with work "
                f"pending ({len(self._live)} live requests); failures: "
                f"{self.failures} — restart_replica() to recover")
            err.fleet_stats = self.stats()
            raise err
        self._dispatch()
        busy = False
        for r, eng in enumerate(self.replicas):
            if self.failed[r]:
                continue
            # host-dispatch-gap hint for bubble attribution: rows still
            # held at the door when this replica ticks
            eng.door_rows = self._door_rows
            try:
                busy = eng.step() or busy
            except ReplicaFailure as e:
                self._fail_replica(r, f"step raised: {e}")
                busy = True                 # the requeued rows are work
                continue
            if self.watchdog_ticks is not None:
                self._watch(r, eng)
        self._measure_service_rate(t_start)
        self._collect()
        return (busy or bool(self.queue) or bool(self._requeue)
                or bool(self._inflight))

    def _default_max_steps(self) -> int:
        """A generous completion bound for ``run``: every live row costs
        at most a few steps (dispatch + pipeline depth + drain), plus
        watchdog + requeue slack per replica.  Normal serving finishes
        in a small fraction of this; only a wedge the watchdog cannot
        clear (or watchdog_ticks=None) reaches it."""
        rows = sum(len(r.images) for r in self._live.values())
        stages = max(len(eng.pipe.stages) for eng in self.replicas)
        slack = (self.watchdog_ticks or 0) + 16
        return 256 + 16 * (rows + len(self._live)) + \
            len(self.replicas) * (stages + slack)

    def run(self, requests: list, *, max_steps: int | None = None) -> list:
        """Submit and drive to completion.  ``max_steps`` is the
        last-resort guard under the per-replica watchdog: if the fleet
        has not drained within it (default: a generous bound computed
        from the offered rows), raise a diagnosable ``TimeoutError``
        carrying the fleet stats (``err.fleet_stats``) instead of
        spinning on ``step()`` forever.  Requests shed by SLO admission
        are returned un-run (``req.rejected``)."""
        for r in requests:
            self.submit(r)
        limit = self._default_max_steps() if max_steps is None else max_steps
        steps = 0
        while self.step():
            steps += 1
            if steps >= limit:
                stuck = [r.rid for r in self._live.values() if not r.done]
                err = TimeoutError(
                    f"fleet did not drain within max_steps={limit} "
                    f"({len(stuck)} requests incomplete: rids {stuck[:8]}"
                    f"{'...' if len(stuck) > 8 else ''}; replicas failed: "
                    f"{self.replicas_failed}, failures: {self.failures})")
                err.fleet_stats = self.stats()
                raise err
        return requests

    def run_batch(self, x) -> np.ndarray:
        """Convenience: one anonymous request, returns stacked logits."""
        req = FrontendRequest(rid=-1, images=np.asarray(x))
        self.run([req])
        return np.asarray(req.logits)

    # -- accounting -----------------------------------------------------
    def reset_stats(self):
        """Zero the wave-scoped statistics (latency reservoir,
        queue-depth high-water mark, dispatch/failure/shed tallies, and
        each replica's schedule tick/bubble/occupancy basis) without
        touching the replicas' compiled state or health flags — benches
        call this between measured waves, while idle.  The reset is ONE
        registry sweep: a statistic is wave-scoped iff ``reset_wave``
        zeroes it, so the coverage audit is structural
        (``metrics.wave_names()``; tested) instead of a hand-maintained
        attribute list.  The service-rate estimate survives (LIFE
        scope): it is calibration the admission controller needs from
        step one of the next wave, not a per-wave statistic."""
        self.metrics.reset_wave()
        self._max_queue_depth.observe(len(self.queue))
        self.failures = []
        for r, eng in enumerate(self.replicas):
            if not self.failed[r]:
                eng.reset_counters()
        self._rows_seen = sum(eng.rows_completed for eng in self.replicas)

    def snapshot(self) -> dict:
        """The registries behind ``stats()``: the door's metrics plus
        each replica engine's (engine + pipe share one registry)."""
        return {"door": self.metrics.snapshot(),
                "replicas": [eng.snapshot() for eng in self.replicas]}

    def stats(self) -> dict:
        reps = [eng.stats() for eng in self.replicas]
        return {
            "n_replicas": len(self.replicas),
            "microbatch": self.microbatch,
            "admit_rows": self.admit_rows,
            "continuous": self.continuous,
            "queue_depth": len(self.queue),
            "max_queue_depth": self.max_queue_depth,
            "door_rows": self._door_rows,
            "requests_done": self.requests_done,
            "rows_dispatched": list(self.rows_dispatched),
            "requests_dispatched": list(self.requests_dispatched),
            # p50/p95 over a bounded sliding window: the most recent
            # latency_window completed requests (latency_samples of them
            # populated) — identical to the old unbounded semantics until
            # the window fills, O(1) memory forever after
            "latency_p50_s": _percentile(self._latencies, 50),
            "latency_p95_s": _percentile(self._latencies, 95),
            "latency_window": self.latency_window,
            "latency_samples": len(self._latencies),
            "replica_bubble": [s["bubble_fraction"] for s in reps],
            "microbatch_occupancy": [s["microbatch_occupancy"]
                                     for s in reps],
            # failure / overload surface (DESIGN.md §10)
            "watchdog_ticks": self.watchdog_ticks,
            "failed": list(self.failed),
            "replicas_failed": self.replicas_failed,
            "failures": list(self.failures),
            "requeues": self.requeues,
            "rows_requeued": self.rows_requeued,
            "slo_p95_s": self.slo_p95_s,
            "rejected": self.rejected_count,
            "rejected_rows": self.rejected_rows,
            "est_row_time_s": self._row_time,
            "est_rows_per_s": (1.0 / self._row_time
                               if self._row_time else None),
            "replicas": reps,
        }

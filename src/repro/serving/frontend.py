"""Replicated-pipeline serving front-end — many Fig 7 chains behind one
front door.

The paper's deployment story does not stop at one multi-chip pipeline:
"heavy traffic from millions of users" means N *data-parallel replicas*
of the layer-pipelined network running over disjoint device groups, the
same scale-out move HPIPE makes across independent device clusters.  At
that point the Memory-Efficient Dataflow literature's lesson applies:
the front door — admission and batching — becomes the bottleneck before
the kernels do, so it gets its own component.

``ResNetFrontend`` owns the shared request queue and N
``serving.pipeline.PipelineEngine`` replicas:

* **Replica carving** — ``launch.mesh.replica_pipeline_devices`` splits
  the local device list into disjoint contiguous groups, one stage chain
  per replica; every replica holds the FULL network (split over its own
  stages), and all replicas share ONE host-side compiled param tree —
  compile once, ``device_put`` per stage (spy-tested in
  tests/test_frontend.py).
* **Admission + routing** — requests wait in the front-door queue until
  the least-loaded replica (by ``PipelineEngine.pending_rows`` — row-
  granular accounting of unsubmitted queue rows plus rows in flight
  through the stages) has room under ``admit_rows``; a request is
  dispatched *whole* to one replica.  (``ConvPipeline.in_flight``
  surfaces each chain's microbatch occupancy in ``stats()``.)
* **Quantization-domain safety** — microbatches are packed per request
  inside one replica (``PipelineEngine._next_microbatch`` never crosses
  a request), so a request's logits are bit-identical to
  ``serving.pipeline.reference_logits`` no matter the replica count,
  arrival order, or interleaving: replicas never share a quantization
  domain, and neither do queue neighbours (DESIGN.md §8).
* **Accounting** — queue depth (current + max), per-replica bubble and
  rows dispatched, and wall-clock request latency (submit -> done)
  reported as p50/p95.

Surface mirrors the existing engines: ``submit`` / ``step`` / ``run`` /
``stats`` (plus ``run_batch`` for one anonymous request).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.compiled_linear import ensure_compiled
from repro.launch.mesh import replica_pipeline_devices
from repro.models import resnet
from repro.serving.pipeline import PipelineEngine, PipelineRequest


@dataclasses.dataclass
class FrontendRequest(PipelineRequest):
    """A ``PipelineRequest`` plus the front-end's lifecycle accounting."""
    replica: int | None = None          # assigned at dispatch
    t_submit: float | None = None
    t_done: float | None = None

    @property
    def latency_s(self) -> float | None:
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit


def _percentile(xs: list, q: float) -> float | None:
    return float(np.percentile(np.asarray(xs), q)) if xs else None


class ResNetFrontend:
    """Admission queue + least-loaded routing over N pipeline replicas."""

    def __init__(self, cfg: resnet.ResNetConfig, params, *,
                 mode: str = "int8", sparsity: float = 0.8,
                 n_replicas: int = 2, n_stages: int = 1,
                 stage_blocks=None, plan=None, microbatch: int = 2,
                 devices=None, admit_rows: int | None = None):
        assert n_replicas >= 1, n_replicas
        self.cfg = cfg
        self.microbatch = microbatch
        # compile ONCE; every replica shares this host-side tree and only
        # device_puts its own stages' subtrees onto its device group
        self.params = ensure_compiled(params, mode, sparsity)
        groups = replica_pipeline_devices(n_replicas, n_stages,
                                          devices=devices)
        self.replicas = [
            PipelineEngine(cfg, self.params, mode=mode, sparsity=sparsity,
                           n_stages=n_stages, stage_blocks=stage_blocks,
                           plan=plan, microbatch=microbatch,
                           devices=groups[r], replica=r)
            for r in range(n_replicas)]
        # front door: a replica chain absorbs n_stages in-flight
        # microbatches; double that before the queue holds requests back
        self.admit_rows = (2 * n_stages * microbatch
                           if admit_rows is None else admit_rows)
        assert self.admit_rows >= 1, (
            "admit_rows must be >= 1 — 0 would deadlock the front door "
            "(an idle replica could never be handed work)", admit_rows)
        self.queue: deque = deque()
        self._inflight: list = []
        self.rows_dispatched = [0] * n_replicas
        self.requests_dispatched = [0] * n_replicas
        self.max_queue_depth = 0
        self._latencies: list[float] = []
        self.requests_done = 0

    # -- request management --------------------------------------------
    def submit(self, req):
        """Admit a request into the front-door queue (routing happens at
        ``step`` time, when replica load is current)."""
        req.logits = None
        req.done = False
        req.replica = None
        req.t_submit = time.perf_counter()
        req.t_done = None
        self.queue.append(req)
        self.max_queue_depth = max(self.max_queue_depth, len(self.queue))

    def _dispatch(self):
        """Route head-of-queue requests to the least-loaded replica while
        it has room under ``admit_rows`` — FIFO order, whole requests
        only (per-request microbatch packing lives in the engine)."""
        while self.queue:
            loads = [eng.pending_rows for eng in self.replicas]
            r = int(np.argmin(loads))
            if loads[r] >= self.admit_rows:
                return                      # backpressure: hold the door
            req = self.queue.popleft()
            req.replica = r
            self.replicas[r].submit(req)
            self.rows_dispatched[r] += len(req.images)
            self.requests_dispatched[r] += 1
            self._inflight.append(req)

    def _collect(self):
        done, still = [], []
        for req in self._inflight:
            (done if req.done else still).append(req)
        now = time.perf_counter()
        for req in done:
            req.t_done = now
            self._latencies.append(req.t_done - req.t_submit)
        self._inflight = still                 # one linear pass per step
        self.requests_done += len(done)
        return done

    def step(self) -> bool:
        """Dispatch what the replicas can absorb, advance every replica
        one tick, and harvest completed requests.  Returns False once the
        whole fleet is idle."""
        self._dispatch()
        busy = False
        for eng in self.replicas:
            busy = eng.step() or busy
        self._collect()
        return busy or bool(self.queue) or bool(self._inflight)

    def run(self, requests: list) -> list:
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return requests

    def run_batch(self, x) -> np.ndarray:
        """Convenience: one anonymous request, returns stacked logits."""
        req = FrontendRequest(rid=-1, images=np.asarray(x))
        self.run([req])
        return np.asarray(req.logits)

    # -- accounting -----------------------------------------------------
    def reset_stats(self):
        """Zero the lifecycle counters (latency samples, queue-depth
        high-water mark, dispatch tallies, and each replica's schedule
        tick/bubble basis) without touching the replicas' compiled state
        — benches call this between measured waves, while idle."""
        self._latencies.clear()
        self.max_queue_depth = len(self.queue)
        self.requests_done = 0
        self.rows_dispatched = [0] * len(self.replicas)
        self.requests_dispatched = [0] * len(self.replicas)
        for eng in self.replicas:
            eng.pipe.reset_counters()

    def stats(self) -> dict:
        reps = [eng.stats() for eng in self.replicas]
        return {
            "n_replicas": len(self.replicas),
            "microbatch": self.microbatch,
            "admit_rows": self.admit_rows,
            "queue_depth": len(self.queue),
            "max_queue_depth": self.max_queue_depth,
            "requests_done": self.requests_done,
            "rows_dispatched": list(self.rows_dispatched),
            "requests_dispatched": list(self.requests_dispatched),
            "latency_p50_s": _percentile(self._latencies, 50),
            "latency_p95_s": _percentile(self._latencies, 95),
            "replica_bubble": [s["bubble_fraction"] for s in reps],
            "replicas": reps,
        }

"""Replicated-pipeline serving front-end — many Fig 7 chains behind one
front door.

The paper's deployment story does not stop at one multi-chip pipeline:
"heavy traffic from millions of users" means N *data-parallel replicas*
of the layer-pipelined network running over disjoint device groups, the
same scale-out move HPIPE makes across independent device clusters.  At
that point the Memory-Efficient Dataflow literature's lesson applies:
the front door — admission and batching — becomes the bottleneck before
the kernels do, so it gets its own component.

``ResNetFrontend`` owns the shared request queue and N
``serving.pipeline.PipelineEngine`` replicas:

* **Replica carving** — ``launch.mesh.replica_pipeline_devices`` splits
  the local device list into disjoint contiguous groups, one stage chain
  per replica; every replica holds the FULL network (split over its own
  stages), and all replicas share ONE host-side compiled param tree —
  compile once, ``device_put`` per stage (spy-tested in
  tests/test_frontend.py).
* **Admission + routing** — requests wait in the front-door queue until
  the least-loaded replica (by ``PipelineEngine.pending_rows`` — O(1)
  row-granular accounting of unsubmitted queue rows plus rows in flight
  through the stages) has room under ``admit_rows``.  Dispatch is ROW
  granular by default (``continuous=True``): the head request hands off
  only as many rows as the least-loaded replica has room for, so two
  small requests can land in one replica back-to-back and share a
  microbatch there (continuous cross-request batching), and a large
  request no longer head-of-line-blocks the door waiting for one replica
  to drain whole.  ``continuous=False`` restores whole-request dispatch
  (the measured baseline in benchmarks/frontend_bench.py).
* **Quantization-domain safety** — quantization domains are PER ROW
  (DESIGN.md §9): one image's logits depend only on its own pixels, so
  any packing — across requests inside a replica's microbatch, or one
  request's rows split across replicas — is bit-identical to
  ``serving.pipeline.reference_logits`` no matter the replica count,
  arrival order, or interleaving.
* **Front-door validation** — ``submit`` rejects malformed requests with
  a clear ``ValueError`` (mirroring ``ServingEngine.submit``'s
  hardening) instead of shape-erroring deep inside a packed microbatch:
  images must be float-castable, rank-4 ``(n, H, W, 3)`` with
  ``H == W == cfg.in_hw``, and finite.  The shape check is load-bearing:
  cross-request packing concatenates rows from different requests, so
  one odd-shaped request would poison its microbatch neighbours' step.
* **Accounting** — queue depth (current + max), per-replica bubble and
  rows dispatched, and wall-clock request latency (submit -> done)
  reported as p50/p95.

Surface mirrors the existing engines: ``submit`` / ``step`` / ``run`` /
``stats`` (plus ``run_batch`` for one anonymous request).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.compiled_linear import ensure_compiled
from repro.launch.mesh import replica_pipeline_devices
from repro.models import resnet
from repro.serving.pipeline import PipelineEngine, PipelineRequest


@dataclasses.dataclass
class FrontendRequest(PipelineRequest):
    """A ``PipelineRequest`` plus the front-end's lifecycle accounting."""
    replica: int | None = None          # first replica assigned at dispatch
    rows_routed: int = 0                # dispatch cursor (continuous mode)
    t_submit: float | None = None
    t_done: float | None = None

    @property
    def latency_s(self) -> float | None:
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit


def _percentile(xs: list, q: float) -> float | None:
    return float(np.percentile(np.asarray(xs), q)) if xs else None


class ResNetFrontend:
    """Admission queue + least-loaded routing over N pipeline replicas."""

    def __init__(self, cfg: resnet.ResNetConfig, params, *,
                 mode: str = "int8", sparsity: float = 0.8,
                 n_replicas: int = 2, n_stages: int = 1,
                 stage_blocks=None, plan=None, microbatch: int = 2,
                 devices=None, admit_rows: int | None = None,
                 continuous: bool = True):
        assert n_replicas >= 1, n_replicas
        self.cfg = cfg
        self.microbatch = microbatch
        self.continuous = continuous
        # compile ONCE; every replica shares this host-side tree and only
        # device_puts its own stages' subtrees onto its device group
        self.params = ensure_compiled(params, mode, sparsity)
        groups = replica_pipeline_devices(n_replicas, n_stages,
                                          devices=devices)
        self.replicas = [
            PipelineEngine(cfg, self.params, mode=mode, sparsity=sparsity,
                           n_stages=n_stages, stage_blocks=stage_blocks,
                           plan=plan, microbatch=microbatch,
                           devices=groups[r], replica=r,
                           pack_requests=continuous)
            for r in range(n_replicas)]
        # front door: a replica chain absorbs n_stages in-flight
        # microbatches; double that before the queue holds requests back
        self.admit_rows = (2 * n_stages * microbatch
                           if admit_rows is None else admit_rows)
        assert self.admit_rows >= 1, (
            "admit_rows must be >= 1 — 0 would deadlock the front door "
            "(an idle replica could never be handed work)", admit_rows)
        self.queue: deque = deque()
        self._inflight: list = []
        self.rows_dispatched = [0] * n_replicas
        self.requests_dispatched = [0] * n_replicas
        self.max_queue_depth = 0
        self._latencies: list[float] = []
        self.requests_done = 0

    # -- request management --------------------------------------------
    def _validate(self, req) -> np.ndarray:
        """Front-door request hardening: reject malformed image payloads
        with a clear ValueError instead of shape-erroring deep inside a
        packed microbatch (where the failure would also take DOWN the
        innocent requests sharing that microbatch)."""
        try:
            images = np.asarray(req.images, dtype=np.float32)
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"request {req.rid}: images must be castable to float32 "
                f"(got {type(req.images).__name__}: {e})") from None
        hw = self.cfg.in_hw
        if images.ndim != 4 or images.shape[1:] != (hw, hw, 3):
            raise ValueError(
                f"request {req.rid}: images must have shape "
                f"(n, {hw}, {hw}, 3) — rows from different requests are "
                f"packed into one microbatch, so every request must match "
                f"the model's input geometry exactly; got "
                f"{images.shape}")
        if images.size and not np.isfinite(images).all():
            raise ValueError(
                f"request {req.rid}: images contain NaN/Inf pixels — a "
                f"non-finite row would corrupt its per-row quantization "
                f"scale and produce garbage logits; sanitize upstream")
        return images

    def submit(self, req):
        """Validate and admit a request into the front-door queue
        (routing happens at ``step`` time, when replica load is
        current).  Raises ValueError on malformed images."""
        req.images = self._validate(req)
        req.logits = None
        req.done = False
        req.replica = None
        req.rows_submitted = req.rows_done = req.rows_routed = 0
        req.t_submit = time.perf_counter()
        req.t_done = None
        if len(req.images) == 0:
            # zero-row request: complete at the front door — it owns no
            # microbatch slot, so don't make a replica tick for it
            req.logits = np.zeros((0, self.cfg.num_classes), np.float32)
            req.done = True
            self._inflight.append(req)      # _collect stamps t_done
            return
        self.queue.append(req)
        self.max_queue_depth = max(self.max_queue_depth, len(self.queue))

    def _dispatch(self):
        """Route head-of-queue rows to the least-loaded replica while it
        has room under ``admit_rows`` — FIFO order.  Continuous mode
        hands off ROWS (the replica packs them into shared microbatches);
        whole-request mode keeps the request intact.  Each hand-off
        reads ``pending_rows`` — O(1), incrementally maintained by the
        engine — so dispatching R requests costs O(R · n_replicas), not
        the O(R²) a per-hand-off queue scan used to cost under load."""
        while self.queue:
            loads = [eng.pending_rows for eng in self.replicas]
            r = int(np.argmin(loads))
            room = self.admit_rows - loads[r]
            if room <= 0:
                return                      # backpressure: hold the door
            req = self.queue[0]
            if self.continuous:
                take = min(room, len(req.images) - req.rows_routed)
                if req.rows_routed == 0:    # first rows of this request
                    req.replica = r
                    self.requests_dispatched[r] += 1
                    self._inflight.append(req)
                self.replicas[r].submit_rows(
                    req, req.rows_routed, req.rows_routed + take)
                req.rows_routed += take
                self.rows_dispatched[r] += take
                if req.rows_routed >= len(req.images):
                    self.queue.popleft()
            else:
                self.queue.popleft()
                req.replica = r
                self.replicas[r].submit(req)
                req.rows_routed = len(req.images)
                self.rows_dispatched[r] += len(req.images)
                self.requests_dispatched[r] += 1
                self._inflight.append(req)

    def _collect(self):
        done, still = [], []
        for req in self._inflight:
            (done if req.done else still).append(req)
        now = time.perf_counter()
        for req in done:
            req.t_done = now
            self._latencies.append(req.t_done - req.t_submit)
        self._inflight = still                 # one linear pass per step
        self.requests_done += len(done)
        return done

    def step(self) -> bool:
        """Dispatch what the replicas can absorb, advance every replica
        one tick, and harvest completed requests.  Returns False once the
        whole fleet is idle."""
        self._dispatch()
        busy = False
        for eng in self.replicas:
            busy = eng.step() or busy
        self._collect()
        return busy or bool(self.queue) or bool(self._inflight)

    def run(self, requests: list) -> list:
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return requests

    def run_batch(self, x) -> np.ndarray:
        """Convenience: one anonymous request, returns stacked logits."""
        req = FrontendRequest(rid=-1, images=np.asarray(x))
        self.run([req])
        return np.asarray(req.logits)

    # -- accounting -----------------------------------------------------
    def reset_stats(self):
        """Zero the lifecycle counters (latency samples, queue-depth
        high-water mark, dispatch tallies, and each replica's schedule
        tick/bubble/occupancy basis) without touching the replicas'
        compiled state — benches call this between measured waves, while
        idle."""
        self._latencies.clear()
        self.max_queue_depth = len(self.queue)
        self.requests_done = 0
        self.rows_dispatched = [0] * len(self.replicas)
        self.requests_dispatched = [0] * len(self.replicas)
        for eng in self.replicas:
            eng.reset_counters()

    def stats(self) -> dict:
        reps = [eng.stats() for eng in self.replicas]
        return {
            "n_replicas": len(self.replicas),
            "microbatch": self.microbatch,
            "admit_rows": self.admit_rows,
            "continuous": self.continuous,
            "queue_depth": len(self.queue),
            "max_queue_depth": self.max_queue_depth,
            "requests_done": self.requests_done,
            "rows_dispatched": list(self.rows_dispatched),
            "requests_dispatched": list(self.requests_dispatched),
            "latency_p50_s": _percentile(self._latencies, 50),
            "latency_p95_s": _percentile(self._latencies, 95),
            "replica_bubble": [s["bubble_fraction"] for s in reps],
            "microbatch_occupancy": [s["microbatch_occupancy"]
                                     for s in reps],
            "replicas": reps,
        }

"""Failure injection for the replicated serving fleet.

The paper's deployment claim — a *persistent multichip* pipeline serving
at 10k im/s/chip — only matters in production if the fleet survives what
production brings: a chip that dies mid-request (fail-stop), a chip that
wedges (a hung DMA, a stuck host thread), or one that silently degrades
to a fraction of its rate.  ``FaultInjector`` manufactures exactly those
three conditions against a live ``serving.pipeline.PipelineEngine``
replica, deterministically, at a chosen step — so the front door's
watchdog + requeue machinery (serving/frontend.py, DESIGN.md §10) can be
tested and benched against the real failure modes instead of hoped at.

Fault semantics (all keyed on the engine's ``step()`` invocation count,
0-based, counted from the moment the fault is armed):

* ``kill``  — fail-stop: the armed invocation raises ``ReplicaFailure``
  before touching engine state, like a device that vanished between
  ticks.  The state it leaves behind is exactly the pre-step state, so
  extraction sees a consistent queue + inlet picture.
* ``hang``  — wedge: from the armed invocation on, ``step()`` returns
  "still busy" without ever advancing the schedule.  Nothing raises —
  only the frontend's progress watchdog can tell a wedged replica from a
  slow one, which is the point.
* ``slow``  — degrade: from the armed invocation on, only every
  ``slow_factor``-th invocation actually ticks; the rest report busy
  without progress.  A replica slowed by less than the watchdog
  threshold limps along and still completes its work; one slowed past it
  is indistinguishable from a hang and gets failed + drained — the
  boundary the watchdog threshold defines.

The injector monkey-wraps ``engine.step`` on the *instance* (the class
is untouched), counts invocations itself, and restores the original
bound method on ``disarm``.  ``ResNetFrontend.restart_replica`` swaps in
a brand-new engine object, which is automatically fault-free.
"""
from __future__ import annotations

import dataclasses


class ReplicaFailure(RuntimeError):
    """A replica died mid-step (the injected fail-stop; a real deployment
    would surface a device error here).  The front door catches this,
    marks the replica failed, and requeues its in-flight rows."""


_KINDS = ("kill", "hang", "slow")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One fault to inject: ``kind`` in {kill, hang, slow}, engaging at
    the ``at_step``-th ``engine.step()`` invocation after arming."""

    kind: str
    at_step: int = 0
    slow_factor: int = 4          # slow mode: tick once per this many calls

    def __post_init__(self):
        assert self.kind in _KINDS, (self.kind, _KINDS)
        assert self.at_step >= 0, self.at_step
        assert self.slow_factor >= 2, self.slow_factor


class FaultInjector:
    """Arms faults against engine instances and restores them on demand."""

    def __init__(self):
        # id(engine) -> (engine, whatever instance-level "step" override
        # existed at arm time, or a sentinel meaning "none: class method")
        self._armed: dict[int, tuple] = {}

    _NO_OVERRIDE = object()

    def arm(self, engine, fault: Fault):
        """Wrap ``engine.step`` so ``fault`` engages at its chosen
        invocation.  One fault per engine at a time; re-arming replaces
        the previous fault (and its invocation counter)."""
        self.disarm(engine)
        orig = engine.step                     # the bound method
        prev = engine.__dict__.get("step", self._NO_OVERRIDE)
        calls = [0]

        def _busyish() -> bool:
            # what a wedged replica reports: work pending, nothing moving
            return engine.pending_rows > 0 or engine.pipe.busy

        def faulty_step() -> bool:
            n = calls[0]
            calls[0] += 1
            if n < fault.at_step:
                return orig()
            if fault.kind == "kill":
                raise ReplicaFailure(
                    f"injected kill at engine step {n} "
                    f"(replica {engine.pipe.replica})")
            if fault.kind == "hang":
                return _busyish()
            if (n - fault.at_step) % fault.slow_factor:
                return _busyish()              # slow: skip this tick
            return orig()

        engine.step = faulty_step
        self._armed[id(engine)] = (engine, prev)

    def disarm(self, engine):
        """Restore the engine's original ``step`` (no-op if not armed):
        the class method becomes visible again, or whatever instance
        override predated arming is put back."""
        entry = self._armed.pop(id(engine), None)
        if entry is not None:
            eng, prev = entry
            if prev is self._NO_OVERRIDE:
                eng.__dict__.pop("step", None)
            else:
                eng.step = prev

    def disarm_all(self):
        for engine, _ in list(self._armed.values()):
            self.disarm(engine)

"""Jamba-v0.1-52B: Mamba+attention 1:7 interleave, MoE 16e top-2 every
second layer [arXiv:2403.19887]."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba_v01_52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536, act="silu",
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    moe_pattern=(False, True),
    ssm=SSMConfig(kind="mamba", d_inner=8192, d_state=16, d_conv=4,
                  dt_rank=256),
    subquadratic=True,  # attention in 4/32 layers only
)

"""OLMoE-1B-7B: 64 experts top-8, qk-norm [arXiv:2409.02060]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe_1b_7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab=50304, rope_theta=1e4, act="silu", qk_norm=True,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
)

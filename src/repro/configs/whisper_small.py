"""Whisper-small: encoder-decoder, conv frontend stubbed (input_specs
provides post-conv frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_small", family="audio",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    head_dim=64, d_ff=3072, vocab=51865, pos="sinusoidal", act="gelu",
    norm="layernorm", encoder_decoder=True, dec_len=448, frontend="audio",
)

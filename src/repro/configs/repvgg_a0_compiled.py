"""RepVGG-A0 Compiled CNN — compile-time branch-fusion model-zoo member
(models/repvgg.py; serve the ``fuse_params`` output)."""
from repro.models.repvgg import RepVGGConfig

CONFIG = RepVGGConfig(width_mult=1.0)

"""MobileNetV2 Compiled CNN — depthwise model-zoo member
(models/mobilenet_v2.py)."""
from repro.models.mobilenet_v2 import MobileNetV2Config

CONFIG = MobileNetV2Config(width_mult=1.0)

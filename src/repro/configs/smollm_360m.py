"""SmolLM-360M: llama-arch small dense GQA [hf:HuggingFaceTB/SmolLM-360M]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm_360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab=49152, rope_theta=1e4, act="silu",
    tie_embeddings=True,
)

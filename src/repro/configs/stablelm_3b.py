"""StableLM-3B: dense MHA (kv=heads), LayerNorm [hf:stabilityai]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm_3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50304, rope_theta=1e4, act="silu", norm="layernorm",
)

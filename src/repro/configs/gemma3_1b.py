"""Gemma3-1B: 5:1 local:global sliding-window, GQA kv=1, huge vocab
[hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144, rope_theta=1e6, act="gelu",
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window=512, qk_norm=True, post_block_norm=True, tie_embeddings=True,
    subquadratic=True,  # 22/26 layers are 512-token sliding window
)

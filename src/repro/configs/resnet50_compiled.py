"""ResNet50 Compiled CNN — the paper's own network (models/resnet.py)."""
from repro.models.resnet import ResNetConfig

CONFIG = ResNetConfig(width_mult=1.0)

"""RWKV6-7B "Finch": attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6_7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536, pos="none", act="relu",
    layer_pattern=("rwkv",),
    ssm=SSMConfig(kind="rwkv6", head_dim=64, decay_lora=64),
    subquadratic=True,
)

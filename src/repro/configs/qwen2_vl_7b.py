"""Qwen2-VL-7B backbone: M-RoPE (16/24/24 sections), vision tower stubbed
[arXiv:2409.12191]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_vl_7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, pos="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1e6, act="silu", frontend="vision",
)

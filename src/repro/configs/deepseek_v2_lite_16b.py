"""DeepSeek-V2-Lite-16B: MLA kv_lora=512, 2 shared + 64 routed top-6
experts, first layer dense [arXiv:2405.04434].

Assignment-sheet note (DESIGN.md SS4): the free-text says "160 routed";
the structured field and the public config say 64 routed — we follow 64.
cfg.d_ff is the layer-0 dense FFN width (10944); expert width is 1408.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek_v2_lite_16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=192,
    d_ff=10944, vocab=102400, rope_theta=1e4, act="silu",
    mla=MLAConfig(kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    first_layer_dense=True,
)

"""Architecture config schema + registry + per-shape input specs.

Every assigned architecture is a frozen ArchConfig constructed in its own
module (configs/<id>.py) and registered here; ``--arch <id>`` resolves via
``get_config``.  ``reduced()`` yields the family-preserving smoke-test
config (small widths/layers/experts) used by tests on CPU.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    gated: bool = True
    act: str = "silu"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"          # mamba | rwkv6
    d_inner: int = 0
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0
    head_dim: int = 64           # rwkv6
    decay_lora: int = 64         # rwkv6


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention
    attn_pattern: tuple = ("global",)   # cycled: 'global' | 'local'
    window: Optional[int] = None
    qk_norm: bool = False
    pos: str = "rope"            # rope | mrope | sinusoidal
    rope_theta: float = 1e4
    mrope_sections: Optional[tuple] = None
    # block mix
    layer_pattern: tuple = ("attn",)    # cycled: 'attn' | 'mamba' | 'rwkv'
    moe: Optional[MoEConfig] = None
    moe_pattern: Optional[tuple] = None  # cycled bools; None -> all dense
    first_layer_dense: bool = False      # deepseek: layer 0 dense FFN
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    n_enc_layers: int = 0
    dec_len: int = 448
    frontend: Optional[str] = None       # 'audio' | 'vision' (stubbed)
    # misc
    act: str = "silu"
    norm: str = "rmsnorm"
    post_block_norm: bool = False        # gemma-style sandwich
    tie_embeddings: bool = False
    subquadratic: bool = False           # long_500k eligible
    norm_eps: float = 1e-6
    max_seq: int = 8192
    unroll: bool = False     # python-loop layers (accurate HLO costs) vs scan
    remat: bool = True       # activation checkpointing on the layer scan

    def layer_sigs(self):
        """Per-layer structural signature list."""
        sigs = []
        for i in range(self.n_layers):
            kind = self.layer_pattern[i % len(self.layer_pattern)]
            is_moe = False
            if self.moe is not None:
                if self.moe_pattern is not None:
                    is_moe = self.moe_pattern[i % len(self.moe_pattern)]
                else:
                    is_moe = True
                if self.first_layer_dense and i == 0:
                    is_moe = False
            attn_type = (self.attn_pattern[i % len(self.attn_pattern)]
                         if kind == "attn" else None)
            sigs.append(dict(kind=kind, moe=is_moe, attn_type=attn_type,
                             index=i))
        return sigs

    def reduced(self):
        """Family-preserving tiny config for CPU smoke tests."""
        changes = dict(
            n_layers=max(min(self.n_layers, 4), len(self.layer_pattern),
                         len(self.attn_pattern),
                         len(self.moe_pattern or (True,))),
            d_model=128,
            n_heads=4, n_kv_heads=min(4, max(1, self.n_kv_heads * 4
                                             // max(self.n_heads, 1))),
            head_dim=32, d_ff=256, vocab=512, max_seq=256, dec_len=16,
        )
        if self.encoder_decoder:
            changes["n_enc_layers"] = 2
            changes["n_layers"] = 2
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                d_ff_expert=64)
        if self.ssm is not None:
            if self.ssm.kind == "mamba":
                changes["ssm"] = dataclasses.replace(
                    self.ssm, d_inner=256, d_state=8, dt_rank=8)
            else:
                changes["ssm"] = dataclasses.replace(self.ssm, head_dim=32,
                                                     decay_lora=16)
        if self.window is not None:
            changes["window"] = 32
        if self.mla is not None:
            changes["mla"] = MLAConfig(kv_lora=64, qk_nope=32, qk_rope=16,
                                       v_dim=32)
            changes["head_dim"] = 48
        if self.mrope_sections is not None:
            changes["mrope_sections"] = (8, 4, 4)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Shapes (assignment): name -> (seq_len, global_batch, step kind)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, step="train"),
    "prefill_32k": dict(seq=32768, batch=32, step="prefill"),
    "decode_32k": dict(seq=32768, batch=128, step="decode"),
    "long_500k": dict(seq=524288, batch=1, step="decode"),
}

ARCH_IDS = (
    "smollm_360m", "gemma3_1b", "stablelm_3b", "phi3_medium_14b",
    "jamba_v01_52b", "deepseek_v2_lite_16b", "olmoe_1b_7b", "rwkv6_7b",
    "whisper_small", "qwen2_vl_7b",
)


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def all_configs() -> dict:
    return {n: get_config(n) for n in ARCH_IDS}


def cell_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """Is (arch x shape) runnable?  (DESIGN.md SS4 skip rules)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode not sub-quadratic"
    return True, ""


def input_specs(cfg: ArchConfig, shape_name: str, dp_override=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    f32, i32 = jnp.float32, jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if sh["step"] == "train":
        if cfg.encoder_decoder:
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": tok(B, cfg.dec_len),
                    "labels": tok(B, cfg.dec_len)}
        batch = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.pos == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return batch
    if sh["step"] == "prefill":
        if cfg.encoder_decoder:
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": tok(B, cfg.dec_len)}
        batch = {"tokens": tok(B, S)}
        if cfg.pos == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return batch
    # decode: one token against a cache of S
    batch = {"token": tok(B, 1)}
    if cfg.pos == "mrope":
        batch["positions"] = jax.ShapeDtypeStruct((3, B, 1), i32)
    return batch
